"""Runtime substrate tests: optimizer, checkpoint/resume, watchdog,
data pipeline determinism, dedup + speculator (paper-technique
integrations), serving engine.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.dedup import CRAMDedup, fingerprint
from repro.data.pipeline import SyntheticLM, TextLM, host_shard
from repro.models import model
from repro.optim import adamw
from repro.runtime import loop, steps
from repro.serving.engine import Engine, Request, generate_greedy
from repro.serving.ngram_cache import NgramSpeculator, verify


CFG = get_config("llama3.2-1b", smoke=True)
OPT = adamw.OptConfig(peak_lr=1e-3, warmup_steps=5, decay_steps=50)


class TestOptimizer:
    def test_schedule_shape(self):
        lrs = [float(adamw.schedule(OPT, jnp.float32(s))) for s in range(60)]
        assert lrs[0] < lrs[4] <= max(lrs)            # warmup rises
        assert lrs[-1] < max(lrs)                     # decays
        assert min(lrs[5:]) >= OPT.peak_lr * OPT.min_lr_ratio * 0.99

    def test_clip(self):
        g = {"a": jnp.full((4,), 100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_update_moves_params(self):
        params = model.init_params(CFG, jax.random.PRNGKey(0))
        state = adamw.init(params)
        grads = jax.tree.map(jnp.ones_like, params)
        new_params, new_state, metrics = adamw.update(OPT, grads, state, params)
        assert int(new_state["step"]) == 1
        diff = adamw.global_norm(jax.tree.map(
            lambda a, b: a - b, params, new_params))
        assert float(diff) > 0

    def test_grad_compression_roundtrip(self):
        cfg8 = adamw.OptConfig(grad_compression="int8")
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                              jnp.float32)}
        out = adamw.decompress(cfg8, adamw.compress(cfg8, g))
        err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
        assert err < float(jnp.max(jnp.abs(g["w"]))) / 100


class TestTrainStep:
    def test_microbatch_equals_full_batch(self):
        """Grad accumulation over microbatches == single big batch."""
        import dataclasses
        cfg1 = dataclasses.replace(CFG, microbatch=1)
        cfg4 = dataclasses.replace(CFG, microbatch=4)
        params = model.init_params(cfg1, jax.random.PRNGKey(0))
        opt_state = adamw.init(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, CFG.vocab, (8, 16))),
                 "labels": jnp.asarray(rng.integers(0, CFG.vocab, (8, 16)))}
        p1, _, m1 = steps.make_train_step(cfg1, OPT)(params, opt_state, batch)
        p4, _, m4 = steps.make_train_step(cfg4, OPT)(params, opt_state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                                   rtol=1e-3)
        d = adamw.global_norm(jax.tree.map(lambda a, b: a - b, p1, p4))
        scale = adamw.global_norm(p1)
        assert float(d) / float(scale) < 1e-3


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_write=False)
        params = model.init_params(CFG, jax.random.PRNGKey(0))
        mgr.save(7, params, blocking=True)
        restored, step = mgr.restore(params)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
        tree = {"w": jnp.arange(4.0)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree, blocking=True)
        assert mgr.latest_step() == 4
        assert mgr.all_steps() == [3, 4]      # GC keeps last 2

    def test_atomicity_partial_dir_ignored(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_write=False)
        tree = {"w": jnp.arange(4.0)}
        mgr.save(1, tree, blocking=True)
        # Simulate a preempted writer: a .tmp dir without manifest.
        (tmp_path / "step_000000002.tmp").mkdir()
        assert mgr.latest_step() == 1

    def test_resume_training_continues(self, tmp_path):
        """Kill/restart: resumed run continues from the checkpoint step."""
        mgr = CheckpointManager(tmp_path, async_write=False)
        data = SyntheticLM(vocab=CFG.vocab, seq_len=16, global_batch=4)
        r1 = loop.train(CFG, OPT, data, 6, ckpt=mgr, ckpt_every=3,
                        log_every=0, log=lambda *_: None)
        assert mgr.latest_step() == 6
        r2 = loop.train(CFG, OPT, data, 10, ckpt=mgr, ckpt_every=100,
                        log_every=0, log=lambda *_: None)
        assert r2.final_step == 10
        assert len(r2.losses) == 4            # only steps 6..9 re-run

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_write=True)
        mgr.save(1, {"w": jnp.arange(8.0)})
        mgr.wait()
        assert mgr.latest_step() == 1


class TestWatchdog:
    def test_straggler_detection_and_snapshot(self, tmp_path):
        import time
        mgr = CheckpointManager(tmp_path, async_write=False)
        data = SyntheticLM(vocab=CFG.vocab, seq_len=16, global_batch=4)

        def delay(step):
            if step == 8:
                time.sleep(1.0)

        res = loop.train(CFG, OPT, data, 10, ckpt=mgr, ckpt_every=0,
                         watchdog_factor=3.0, step_hook=delay,
                         log_every=0, log=lambda *_: None)
        assert any(e.step == 8 for e in res.straggler_events)
        # the watchdog snapshotted mid-run
        assert 9 in mgr.all_steps() or mgr.latest_step() is not None


class TestData:
    def test_deterministic_seek(self):
        d = SyntheticLM(vocab=100, seq_len=8, global_batch=4, seed=3)
        a = d.batch_at(17)
        b = d.batch_at(17)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_are_next_tokens(self):
        d = SyntheticLM(vocab=100, seq_len=8, global_batch=4)
        b = d.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_text_pipeline(self):
        corpus = bytes(range(256)) * 20
        d = TextLM(corpus=corpus, seq_len=16, global_batch=2)
        b = d.batch_at(0)
        assert b["tokens"].shape == (2, 16)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_shard(self):
        d = SyntheticLM(vocab=100, seq_len=8, global_batch=8)
        b = d.batch_at(0)
        s0 = host_shard(b, 0, 4)
        s3 = host_shard(b, 3, 4)
        assert s0["tokens"].shape == (2, 8)
        np.testing.assert_array_equal(s3["tokens"], b["tokens"][6:8])


class TestDedup:
    def test_exact_duplicate_detected(self):
        d = CRAMDedup(threshold=0.95)
        doc = b"the quick brown fox jumps over the lazy dog" * 4
        d.add(doc)
        assert d.is_duplicate(doc)

    def test_distinct_not_detected(self):
        rng = np.random.default_rng(0)
        d = CRAMDedup(threshold=0.9)
        d.add(rng.bytes(200))
        assert not d.is_duplicate(rng.bytes(200))

    def test_filter_keeps_first_of_pair(self):
        rng = np.random.default_rng(1)
        a, b = rng.bytes(200), rng.bytes(200)
        kept = CRAMDedup(threshold=0.9).filter([a, a, b, b, a])
        assert len(kept) == 2

    def test_shifted_duplicate_detected(self):
        """Sliding alignment catches prefix-shifted near-dups."""
        rng = np.random.default_rng(2)
        base = rng.bytes(300)
        d = CRAMDedup(threshold=0.9)
        d.add(base)
        assert d.is_duplicate(base[4:] )


class TestSpeculator:
    def test_propose_recalls_history(self):
        spec = NgramSpeculator(suffix_tokens=4)
        seq = list(np.random.default_rng(0).integers(0, 50000, 64))
        spec.feed(seq)
        # suffix = tokens 20..24 -> proposal should be tokens 24..28
        prop, conf = spec.propose(seq[20:24], k=4)
        assert conf == 1.0
        assert verify(prop, np.asarray(seq[24:28])) == 4

    def test_low_confidence_on_unseen(self):
        spec = NgramSpeculator()
        spec.feed(list(range(100, 164)))
        prop, conf = spec.propose([1, 2, 3, 4], k=4)
        assert conf < 1.0


class TestServing:
    def test_generate_greedy_deterministic(self):
        params = model.init_params(CFG, jax.random.PRNGKey(0))
        prompts = np.random.default_rng(0).integers(
            0, CFG.vocab, (2, 6), dtype=np.int32)
        a = generate_greedy(CFG, params, prompts, max_new=5, max_seq=32)
        b = generate_greedy(CFG, params, prompts, max_new=5, max_seq=32)
        np.testing.assert_array_equal(a, b)

    def test_engine_serves_all_requests(self):
        params = model.init_params(CFG, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, CFG.vocab, 4, dtype=np.int32),
                        max_new=6) for _ in range(3)]
        eng = Engine(CFG, params, max_seq=32, n_slots=2)
        eng.run(list(reqs))
        assert all(len(r.out) == 6 for r in reqs)

    def test_engine_rejects_empty_prompt(self):
        """A zero-length prompt has no logits to seed decoding from; the
        engine must reject it instead of crashing on an unbound local."""
        params = model.init_params(CFG, jax.random.PRNGKey(0))
        eng = Engine(CFG, params, max_seq=32, n_slots=1)
        with pytest.raises(ValueError, match="empty prompt"):
            eng.add(Request(prompt=np.zeros(0, np.int32), max_new=4))
        # The engine stays usable: no slot was consumed by the rejection.
        req = Request(prompt=np.array([1, 2], np.int32), max_new=2)
        assert eng.add(req)

    def test_engine_rejects_oversized_prompt(self):
        """Cache rows past max_seq-1 don't exist; the per-slot scatter
        write would silently drop them, so admission must reject."""
        params = model.init_params(CFG, jax.random.PRNGKey(0))
        eng = Engine(CFG, params, max_seq=8, n_slots=1)
        with pytest.raises(ValueError, match="exceeds"):
            eng.add(Request(prompt=np.arange(8, dtype=np.int32), max_new=2))
        assert eng.add(Request(prompt=np.arange(7, dtype=np.int32),
                               max_new=2))

    def test_engine_mixed_prompt_lengths(self):
        """Slots admitted with different prompt lengths must decode at
        their own cache positions; decoding every active slot at
        max(slot_pos) wrote short-prompt slots' KV rows at the wrong
        positions and produced garbage once lengths diverged."""
        params = model.init_params(CFG, jax.random.PRNGKey(0))
        rng = np.random.default_rng(2)
        p_short = rng.integers(0, CFG.vocab, 3, dtype=np.int32)
        p_long = rng.integers(0, CFG.vocab, 9, dtype=np.int32)
        refs = [generate_greedy(CFG, params, p[None], max_new=5,
                                max_seq=32)[0] for p in (p_short, p_long)]
        reqs = [Request(prompt=p_short, max_new=5),
                Request(prompt=p_long, max_new=5)]
        eng = Engine(CFG, params, max_seq=32, n_slots=2)
        eng.run(list(reqs))
        for req, ref in zip(reqs, refs):
            np.testing.assert_array_equal(np.asarray(req.out), ref)

    def test_engine_slot_reuse_isolated_from_predecessor(self):
        """A request admitted to a freed slot must not attend the previous
        occupant's KV rows: the slot position resets to 0 on free, and the
        causal mask hides the stale cache until it is overwritten."""
        params = model.init_params(CFG, jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        prompts = [rng.integers(0, CFG.vocab, n, dtype=np.int32)
                   for n in (5, 7, 4)]
        refs = [generate_greedy(CFG, params, p[None], max_new=4,
                                max_seq=32)[0] for p in prompts]
        reqs = [Request(prompt=p, max_new=4) for p in prompts]
        eng = Engine(CFG, params, max_seq=32, n_slots=2)   # forces reuse
        eng.run(list(reqs))
        for req, ref in zip(reqs, refs):
            np.testing.assert_array_equal(np.asarray(req.out), ref)

    def test_engine_matches_generate(self):
        """Slot-based engine output == batched greedy generation."""
        params = model.init_params(CFG, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, CFG.vocab, 6, dtype=np.int32)
        ref = generate_greedy(CFG, params, prompt[None], max_new=5,
                              max_seq=32)[0]
        req = Request(prompt=prompt, max_new=5)
        eng = Engine(CFG, params, max_seq=32, n_slots=1)
        eng.run([req])
        np.testing.assert_array_equal(np.asarray(req.out), ref)
