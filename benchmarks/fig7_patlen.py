"""Paper Fig. 7: sensitivity to pattern length (OracularOpt): throughput
stays close to the 100-char baseline, efficiency decreases."""

import time

from repro.core import costmodel as cm
from repro.core.tech import NEAR_TERM


def run():
    rows = []
    base = None
    for plen in (100, 200, 300):
        t0 = time.perf_counter()
        d = cm.Design(tech=NEAR_TERM, opt=True, pattern_chars=plen)
        r = cm.run_workload(d, 3_000_000, "oracular")
        us = (time.perf_counter() - t0) * 1e6
        if base is None:
            base = r
        rows.append((f"fig7/P{plen}", round(us, 1),
                     f"rate={r.match_rate:.4g}/s"
                     f" rel_rate={r.match_rate/base.match_rate:.3f}"
                     f" eff={r.efficiency:.4g}"
                     f" rel_eff={r.efficiency/base.efficiency:.3f}"))
    return rows
