"""Hypothesis property tests on system invariants.

Skipped wholesale when ``hypothesis`` is absent (dev dep; see
requirements-dev.txt) -- never an import error at collection.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import costmodel as cm
from repro.core import encoding
from repro.core.isa import CodeGen, ColumnAllocator
from repro.core.matcher import sliding_scores
from repro.core.scheduler import expected_candidates, schedule_oracular
from repro.core.tech import NEAR_TERM


dna = st.integers(0, 3)


class TestEncodingProperties:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(dna, min_size=1, max_size=200))
    def test_pack_roundtrip(self, codes):
        arr = np.array([codes], np.uint8)
        words = encoding.pack_codes_u32(arr)
        np.testing.assert_array_equal(
            encoding.unpack_codes_u32(words, len(codes)), arr)

    @settings(max_examples=30, deadline=None)
    @given(st.lists(dna, min_size=1, max_size=100))
    def test_bits_roundtrip(self, codes):
        arr = np.array([codes], np.uint8)
        np.testing.assert_array_equal(
            encoding.bits_to_codes(encoding.codes_to_bits(arr)), arr)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(10, 300), st.integers(2, 9), st.integers(0, 2**31))
    def test_fold_preserves_every_window(self, ref_len, p, seed):
        rng = np.random.default_rng(seed)
        ref = rng.integers(0, 4, ref_len, np.uint8)
        frag_len = min(ref_len, max(3 * p, 16))
        frags = encoding.fold_reference(ref, frag_len, p)
        step = frag_len - (p - 1)
        # every window of ref is fully contained in some fragment
        for loc in range(0, ref_len - p + 1, max((ref_len - p) // 10, 1)):
            assert any(
                r * step <= loc and loc + p <= r * step + frag_len
                for r in range(frags.shape[0]))


class TestMatcherProperties:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 5), st.integers(4, 60), st.integers(0, 2**31),
           st.data())
    def test_score_bounds(self, r, f, seed, data):
        p = data.draw(st.integers(1, f))
        rng = np.random.default_rng(seed)
        frags = rng.integers(0, 4, (r, f), np.uint8)
        pat = rng.integers(0, 4, p, np.uint8)
        s = sliding_scores(frags, pat)
        assert s.shape == (r, f - p + 1)
        assert (s >= 0).all() and (s <= p).all()

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31))
    def test_shift_invariance(self, seed):
        """Prepending one char shifts all alignment scores by one."""
        rng = np.random.default_rng(seed)
        frag = rng.integers(0, 4, (1, 40), np.uint8)
        pat = rng.integers(0, 4, 8, np.uint8)
        shifted = np.concatenate(
            [rng.integers(0, 4, (1, 1), np.uint8), frag], axis=1)
        a = sliding_scores(frag, pat)
        b = sliding_scores(shifted, pat)
        np.testing.assert_array_equal(b[:, 1:], a)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31))
    def test_reverse_complement_symmetry(self, seed):
        """Scores are invariant under relabeling the alphabet (matching is
        equality-based, not value-based)."""
        rng = np.random.default_rng(seed)
        frags = rng.integers(0, 4, (2, 30), np.uint8)
        pat = rng.integers(0, 4, 6, np.uint8)
        perm = rng.permutation(4).astype(np.uint8)
        np.testing.assert_array_equal(
            sliding_scores(frags, pat),
            sliding_scores(perm[frags], perm[pat]))


class TestAdderTreeProperties:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 64), st.integers(0, 2**31))
    def test_popcount_tree_any_width(self, n_bits, seed):
        rng = np.random.default_rng(seed)
        data = rng.integers(0, 2, (4, n_bits), np.uint8)
        cg = CodeGen(ColumnAllocator(n_bits, n_bits + 512, reuse_lo=0))
        cols = cg.popcount_tree(list(range(n_bits)))
        from repro.core.array import CRAMArray
        arr = CRAMArray(4, n_bits + 512)
        arr.write_column_rows(0, data)
        arr.run(cg.prog)
        weights = 1 << np.arange(len(cols))
        got = (np.stack([np.asarray(arr.state[:, c]) for c in cols], -1)
               * weights).sum(-1)
        np.testing.assert_array_equal(got, data.sum(1))

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 128))
    def test_fa_count_near_optimal(self, n_bits):
        """FA count of the reduction tree stays within 2.2x of n (the
        paper's 188-for-100 implies ~1.9x)."""
        cg = CodeGen(ColumnAllocator(n_bits, n_bits + 1024, reuse_lo=0))
        cg.popcount_tree(list(range(n_bits)))
        assert cg.fa_count() <= max(2.2 * n_bits, 6)


class TestCostModelProperties:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(20, 400))
    def test_per_alignment_energy_monotone_in_pattern_length(self, plen):
        """Longer patterns do strictly more work *per alignment* (whole-pass
        energy can shrink because the fragment compartment shrinks)."""
        d1 = cm.Design(tech=NEAR_TERM, opt=True, pattern_chars=plen)
        d2 = cm.Design(tech=NEAR_TERM, opt=True, pattern_chars=plen + 50)
        p1, p2 = cm.pass_cost(d1), cm.pass_cost(d2)
        assert (p2.energy_j / p2.n_alignments
                > p1.energy_j / p1.n_alignments)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(12, 18))
    def test_longer_seeds_fewer_candidates(self, k):
        a = expected_candidates(3e9, 100, k)
        b = expected_candidates(3e9, 100, k + 1)
        assert b < a

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31))
    def test_oracular_never_misses_planted_pattern(self, seed):
        """Soundness of the k-mer filter: a pattern planted in a fragment is
        always scheduled onto that row."""
        rng = np.random.default_rng(seed)
        frags = rng.integers(0, 4, (8, 40), np.uint8)
        row = int(rng.integers(0, 8))
        pat = frags[row, 5:25].copy()
        s = schedule_oracular(frags, pat[None, :], k=8)
        # schedule maps row -> pattern index per pass
        assert any(assign.get(row) == 0 for assign in s.passes)
