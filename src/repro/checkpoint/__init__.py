"""repro.checkpoint"""
