"""Online-ingestion bench: corpus growth under live query traffic.

The regime the paper's resident-reference design exists for (DESIGN.md
Sec. 3f): the store keeps serving queries while new documents stream in.
Two scenarios:

* ``service_mixed`` -- a ``MatchService`` over one resident corpus takes
  interleaved ``ingest`` + ``submit`` traffic; each tick applies one
  batched in-place ``append_rows`` then serves the tick's queries.
  Reported: docs/s ingested *while* serving, and QPS served *while*
  ingesting.  Asserted: zero host repacks of resident rows across all
  growth (pack counters flat after the warm-up pack), and the final
  post-growth results bit-identical to a fresh engine packed from scratch
  on the grown corpus.
* ``dedup_growth`` -- a ``CRAMDedup`` store crosses its capacity boundary
  under ``filter`` traffic.  Asserted: the store's ``MatchEngine`` is the
  same object before and after growth (no rebuild on doubling) and the
  lifetime pack counters stay <= one per device form.

Both paths run on the planner's choice of kernel; correctness is asserted
before any number is reported.  Emits ``BENCH_match_ingest.json`` at the
repo root and exits nonzero if the record is malformed.  CI runs
``--smoke`` as a schema guard: same pipeline and validation on a reduced
shape, without overwriting the committed full-run artifact.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_match_ingest.json"

FULL = dict(R0=64, F=256, P=32, n_docs=192, ingest_batch=4, q_per_tick=2,
            dedup_docs=80)
SMOKE = dict(R0=16, F=128, P=16, n_docs=24, ingest_batch=4, q_per_tick=1,
             dedup_docs=12)

REQUIRED_KEYS = ("shape", "device_kind", "backend", "calibration",
                 "n_processes", "n_hosts", "interpret", "smoke", "results")
REQUIRED_RESULT_KEYS = ("scenario", "n_docs", "docs_per_s",
                        "resident_repacks", "engine_stable", "identical")


def bench_service_mixed(cfg: dict, rng) -> dict:
    """Mixed ingest+query ticks through one MatchService."""
    from repro.match import MatchEngine, MatchQuery, MatchService

    R0, F, P = cfg["R0"], cfg["F"], cfg["P"]
    frags = rng.integers(0, 4, (R0, F), np.uint8)
    eng = MatchEngine(frags)
    svc = MatchService(eng)
    docs = rng.integers(0, 4, (cfg["n_docs"], F), np.uint8)
    pats = rng.integers(0, 4, (cfg["n_docs"], P), np.uint8)

    # Warm-up: build the device forms and the jit caches so the timed
    # loop (and the pack-counter assertion) isolates growth.
    svc.match(MatchQuery.exact(pats[0]))
    rows_before = eng.corpus.n_rows

    n_q = 0
    t0 = time.perf_counter()
    for i in range(0, cfg["n_docs"], cfg["ingest_batch"]):
        svc.ingest(docs[i:i + cfg["ingest_batch"]])
        for j in range(cfg["q_per_tick"]):
            svc.submit(MatchQuery.exact(pats[(i + j) % len(pats)]))
            n_q += 1
        svc.tick()
    svc.flush()
    dt = time.perf_counter() - t0

    n_docs = eng.corpus.n_rows - rows_before
    # Packs beyond the lazy first one per form are resident repacks; a
    # first-pack of the *other* form (batched roofline flipping kernels)
    # is legitimate and must not trip the invariant.
    repacks = (max(0, eng.corpus.swar_pack_count - 1)
               + max(0, eng.corpus.onehot_pack_count - 1))
    # Post-growth correctness: the served store must be bit-identical to
    # an engine packed from scratch on the grown corpus.
    probe = MatchQuery.exact(pats[1])
    got = svc.match(probe)
    oracle = MatchEngine(np.array(eng.corpus.fragments)).match(probe)
    identical = (np.array_equal(got.best_scores, oracle.best_scores)
                 and np.array_equal(got.best_locs, oracle.best_locs))
    return {
        "scenario": "service_mixed",
        "n_docs": int(n_docs),
        "docs_per_s": round(n_docs / dt, 1),
        "qps_while_ingesting": round(n_q / dt, 1),
        "n_queries_served": n_q,
        "rows": [int(rows_before), int(eng.corpus.n_rows)],
        "capacity": int(eng.corpus.capacity),
        "resident_repacks": int(repacks),
        "engine_stable": True,           # the service never rebuilds it
        "identical": bool(identical),
        "ingest_batches": svc.stats.n_ingest_batches,
        "service_stats": svc.stats.snapshot(),
    }


def bench_dedup_growth(cfg: dict, rng) -> dict:
    """CRAMDedup crossing its capacity boundary: no engine rebuild."""
    from repro.data.dedup import CRAMDedup, _INITIAL_CAPACITY

    d = CRAMDedup(threshold=1.01)        # never a duplicate: every doc adds
    engine_before = d.engine
    n = max(cfg["dedup_docs"], _INITIAL_CAPACITY + 8)  # force >= 1 doubling
    docs = [rng.bytes(cfg["F"]) for _ in range(n)]
    t0 = time.perf_counter()
    kept = d.filter(docs)
    dt = time.perf_counter() - t0
    engine_stable = d.engine is engine_before
    return {
        "scenario": "dedup_growth",
        "n_docs": len(kept),
        "docs_per_s": round(len(kept) / dt, 1),
        "rows": [0, len(d)],
        "capacity": d.capacity,
        # Lazy first pack per form is the warm-up, not a repack of
        # resident rows; growth must add zero on top of one per form.
        "resident_repacks": (
            max(0, d.engine.corpus.swar_pack_count - 1)
            + max(0, d.engine.corpus.onehot_pack_count - 1)),
        "host_packs": d.total_host_packs,
        "row_writes": d.total_row_writes,
        "engine_stable": bool(engine_stable),
        "identical": len(kept) == n,     # threshold>1: nothing may drop
    }


def validate(record: dict) -> None:
    """Schema guard: fail loudly if the BENCH artifact is malformed."""
    for key in REQUIRED_KEYS:
        if key not in record:
            raise ValueError(f"BENCH record missing key {key!r}")
    if not (record["calibration"] == "static"
            or record["calibration"].startswith("calibrated:")):
        raise ValueError("malformed calibration provenance: "
                         f"{record['calibration']!r}")
    if not record["results"]:
        raise ValueError("BENCH record has no results")
    for row in record["results"]:
        for key in REQUIRED_RESULT_KEYS:
            if key not in row:
                raise ValueError(f"result row missing key {key!r}: {row}")
        if row["resident_repacks"] != 0:
            raise ValueError(
                f"{row['scenario']}: {row['resident_repacks']} host "
                "repack(s) of resident rows during growth (must be 0)")
        if not row["engine_stable"]:
            raise ValueError(f"{row['scenario']}: engine was rebuilt on "
                             "growth")
        if not row["identical"]:
            raise ValueError(f"{row['scenario']}: post-growth results "
                             "diverged from the from-scratch oracle")
        if row["docs_per_s"] <= 0:
            raise ValueError(f"{row['scenario']}: non-positive ingest "
                             "throughput")
    json.loads(json.dumps(record))      # round-trips as JSON


def run_bench(smoke: bool) -> dict:
    from repro.match import engine as _engine

    cfg = SMOKE if smoke else FULL
    rng = np.random.default_rng(11)
    results = [bench_service_mixed(cfg, rng), bench_dedup_growth(cfg, rng)]
    from repro.match.calibrate import bench_provenance
    record = {
        "shape": {k: cfg[k] for k in
                  ("R0", "F", "P", "n_docs", "ingest_batch", "q_per_tick")},
        **bench_provenance(),
        "interpret": _engine.default_interpret(),
        "smoke": smoke,
        "results": results,
    }
    validate(record)
    if not smoke:
        # Smoke mode (the CI schema guard) must not clobber the committed
        # full-run artifact with reduced shapes.
        BENCH_JSON.write_text(json.dumps(record, indent=2) + "\n")
    return record


def run(smoke: bool = False):
    """``benchmarks.run`` driver hook: (name, us_per_call, derived) rows."""
    record = run_bench(smoke)
    return [
        (f"ingest/{row['scenario']}",
         round(1e6 / max(row["docs_per_s"], 1e-9), 1),
         f"docs_per_s={row['docs_per_s']} "
         f"repacks={row['resident_repacks']} "
         f"engine_stable={row['engine_stable']} "
         f"identical={row['identical']}")
        for row in record["results"]
    ]


def artifact_summary() -> str:
    """One greppable line from the committed artifact (perf trajectory)."""
    if not BENCH_JSON.exists():
        return ""
    rec = json.loads(BENCH_JSON.read_text())
    cases = " ".join(f"{r['scenario']}:docs_per_s={r['docs_per_s']}:"
                     f"repacks={r['resident_repacks']}"
                     for r in rec["results"])
    return f"{BENCH_JSON.name} {cases}"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus + fewer docs (CI schema guard)")
    args = ap.parse_args()
    try:
        record = run_bench(args.smoke)
    except ValueError as e:
        print(f"BENCH validation failed: {e}", file=sys.stderr)
        return 1
    for row in record["results"]:
        extra = (f"  qps={row['qps_while_ingesting']}"
                 if "qps_while_ingesting" in row else "")
        print(f"{row['scenario']:>14}  docs/s={row['docs_per_s']:>8.1f}"
              f"{extra}  repacks={row['resident_repacks']}  "
              f"engine_stable={row['engine_stable']}  "
              f"identical={row['identical']}")
    if args.smoke:
        print("smoke: record validated, artifact not written")
    else:
        print(f"wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
