"""Runtime feedback for the cost model (DESIGN.md Sec. 3i).

Even a calibrated cost model drifts: corpora change shape, the host gets
contended, a backend upgrade moves kernel constants.  The serving half of
the calibration discipline is therefore *online*: every executed launch
records its observed wall time against the estimate the planner priced it
at, bucketed by (kernel, shape octave), and once a bucket's measured /
estimated ratio drifts past a bound the planner re-prices that bucket by
the measured ratio -- so a mispredicted mxu-vs-swar or scan-vs-filter
decision heals within a few launches instead of never.

This generalizes the measured-selectivity EWMA that ``CorpusIndex``
pioneered for the filter stage (``record_selectivity``) into one shared
idiom -- ``EwmaRatio`` -- used by both: a clamped exponentially-weighted
average of measured/predicted ratios, always taken against the *raw*
(un-fed-back) prediction so the loop converges to the truth rather than
the geometric mean of model and truth.

Keys are coarse by design: shapes bucket by octave (``floor(log2)``), so
one bucket aggregates the launches that share a cost regime and a handful
of observations is enough to re-price it.  The first observation per
bucket is discarded as warmup (it pays jit tracing/compilation, which is
not a marginal-launch cost).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

# Shared EWMA defaults (the CorpusIndex selectivity values, kept).
DEFAULT_DECAY = 0.3
# Runtime ratios span decades on a mispriced substrate (static TPU
# constants vs. an interpret-mode CPU); the clamp only guards single-shot
# garbage (timer glitches), not honest large ratios.
RUNTIME_RATIO_CLAMP = (1e-4, 1e4)


class EwmaRatio:
    """Clamped EWMA of measured/predicted ratios.

    ``update`` folds one observation in and returns the new value; the
    value is ``None`` until the first update (callers treat that as
    "no evidence: use the raw model").  The per-update clamp bounds the
    influence of any single wild observation -- walking the estimate a
    long way requires *consistent* evidence.
    """

    __slots__ = ("decay", "clamp", "value", "n")

    def __init__(self, decay: float = DEFAULT_DECAY,
                 clamp: Tuple[float, float] = (0.1, 10.0)):
        self.decay = float(decay)
        self.clamp = (float(clamp[0]), float(clamp[1]))
        self.value: Optional[float] = None
        self.n = 0

    def update(self, ratio: float) -> float:
        ratio = min(max(float(ratio), self.clamp[0]), self.clamp[1])
        prev = 1.0 if self.value is None else self.value
        self.value = (1.0 - self.decay) * prev + self.decay * ratio
        self.n += 1
        return self.value


def octave(v: float) -> int:
    """Shape-bucket coordinate: floor(log2(v)), 0 for v < 1."""
    v = int(v)
    return v.bit_length() - 1 if v > 0 else 0


def kernel_key(kernel: str, R: int, x: int, Q: int) -> Tuple:
    """Feedback bucket for one kernel dispatch.

    ``x`` is the kernel's second extent: pattern chars for the match
    kernels, signature words for the filter kernel.  Octave bucketing
    groups launches that share a cost regime; estimates within a bucket
    differ by at most ~2x from the bucket's edges, well inside the drift
    bound that gates re-pricing.
    """
    return (kernel, octave(R), octave(x), octave(Q))


class _Cell:
    __slots__ = ("ewma", "n", "warmed", "published")

    def __init__(self, decay: float):
        self.ewma = EwmaRatio(decay=decay, clamp=RUNTIME_RATIO_CLAMP)
        self.n = 0              # post-warmup observations
        self.warmed = False     # first (compile-paying) observation seen
        self.published = 1.0    # factor exposed to the planner


class FeedbackStore:
    """Per-(kernel, shape-bucket) observed/estimated runtime feedback.

    * ``observe(key, est, observed)`` -- fold one executed launch in.
      ``est`` must be the feedback-*free* estimate (the planner divides
      its published factor back out before recording), so the EWMA
      converges to truth/model, not a fixed point between them.
    * ``factor(key)`` -- multiplier the planner applies to that bucket's
      price: 1.0 until the bucket has ``min_samples`` post-warmup
      observations AND its EWMA sits outside ``[1/drift_bound,
      drift_bound]``; the EWMA ratio from then on (a re-priced bucket
      keeps tracking, it never snaps back to 1).
    * ``version`` -- bumped whenever some bucket's published factor moves
      materially (> ``publish_tol``); compiled plans watch it and
      re-price lazily on their next run.
    """

    def __init__(self, *, drift_bound: float = 2.0, min_samples: int = 3,
                 decay: float = 0.5, publish_tol: float = 1.2):
        if drift_bound <= 1.0:
            raise ValueError("drift_bound must be > 1")
        self.drift_bound = float(drift_bound)
        self.min_samples = int(min_samples)
        self.decay = float(decay)
        self.publish_tol = float(publish_tol)
        self._cells: Dict[Tuple, _Cell] = {}
        self.version = 0
        self.n_observations = 0       # post-warmup observations folded in
        self.n_mispredictions = 0     # ... whose ratio fell outside bound

    # -- recording ------------------------------------------------------------
    def observe(self, key: Tuple, est_s: float, observed_s: float) -> None:
        if est_s <= 0.0 or observed_s <= 0.0:
            return
        cell = self._cells.get(key)
        if cell is None:
            cell = self._cells[key] = _Cell(self.decay)
        if not cell.warmed:
            # First execution in this bucket pays jit tracing/compilation;
            # that is not a marginal-launch cost, so it must not seed the
            # EWMA (one 100x outlier would re-price the bucket for good).
            cell.warmed = True
            return
        ratio = observed_s / est_s
        cell.ewma.update(ratio)
        cell.n += 1
        self.n_observations += 1
        if not (1.0 / self.drift_bound <= ratio <= self.drift_bound):
            self.n_mispredictions += 1
        self._publish(cell)

    def _publish(self, cell: _Cell) -> None:
        new = self._factor_of(cell)
        moved = max(new, cell.published) / max(
            min(new, cell.published), 1e-12)
        if moved > self.publish_tol:
            cell.published = new
            self.version += 1

    # -- pricing --------------------------------------------------------------
    def _factor_of(self, cell: _Cell) -> float:
        if cell.n < self.min_samples or cell.ewma.value is None:
            return 1.0
        v = cell.ewma.value
        if 1.0 / self.drift_bound <= v <= self.drift_bound:
            # Within the bound the model is "right enough": leave the
            # price alone so near-tie decisions stay deterministic.
            return 1.0 if cell.published == 1.0 else v
        return v

    def factor(self, key: Tuple) -> float:
        cell = self._cells.get(key)
        return 1.0 if cell is None else cell.published

    # -- introspection --------------------------------------------------------
    @property
    def misprediction_rate(self) -> float:
        return (self.n_mispredictions / self.n_observations
                if self.n_observations else 0.0)

    def repriced(self) -> Dict[Tuple, float]:
        """Buckets currently priced away from the model, with factors."""
        return {k: c.published for k, c in self._cells.items()
                if not math.isclose(c.published, 1.0)}

    def keys(self) -> Tuple[Tuple, ...]:
        """Every (kernel, shape-bucket) key seen so far (warmup included).

        The same tuples the obs-layer plan-vs-actual registry keys on
        (both receive the identical ``kernel_key`` from the engine), so
        joining the two accountings is a dict lookup.
        """
        return tuple(self._cells.keys())

    def cell_stats(self, key: Tuple) -> Optional[Dict]:
        """One bucket's state: post-warmup count, EWMA ratio, factor."""
        cell = self._cells.get(key)
        if cell is None:
            return None
        return {"n": cell.n, "warmed": cell.warmed,
                "ewma_ratio": cell.ewma.value,
                "published_factor": cell.published}

    def snapshot(self) -> Dict:
        return {
            "n_observations": self.n_observations,
            "n_mispredictions": self.n_mispredictions,
            "misprediction_rate": round(self.misprediction_rate, 4),
            "n_buckets": len(self._cells),
            "n_repriced": len(self.repriced()),
            # JSON-safe per-bucket factors for the re-priced set: the
            # drift a ServiceStats snapshot should make visible, not
            # just count.
            "repriced_factors": {
                "/".join(str(p) for p in k): round(v, 4)
                for k, v in sorted(self.repriced().items(),
                                   key=lambda kv: str(kv[0]))},
            "version": self.version,
        }
