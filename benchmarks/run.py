# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--only X]``.

Modules (one per paper table/figure + assignment deliverables):
  table1_gates      -- Table 1/3 gate windows + truth tables
  fig5_throughput   -- Fig. 5 Naive/Oracular x Opt throughput/energy
  fig6_breakdown    -- Fig. 6 stage breakdown
  fig7_patlen       -- Fig. 7 pattern-length sensitivity
  fig8_tech         -- Fig. 8 MTJ technology sensitivity
  fig9_10_nmp       -- Figs. 9/10 vs NMP / NMP-Hyp
  fig11_gates       -- Fig. 11 bulk bitwise vs Ambit/Pinatubo
  table4_apps       -- Table 4 benchmark apps
  kernel_bench      -- TPU-adapted kernel engine (beyond paper)
  service_bench     -- multi-tenant match service coalescing (beyond paper)
  query_bench       -- compiled-query reuse + wildcard predicates (beyond)
  ingest_bench      -- online ingestion into a live store (beyond paper)
  filter_bench      -- q-gram filter-then-verify vs full scan (beyond)
  standing_bench    -- fused standing-query bank vs per-pattern loop
  shard_bench       -- mesh-sharded 1M-row scaling sweep (beyond paper)
  calibrate_bench   -- autotuned cost model: the three Sec. 3i proofs
  obs_bench         -- tracing/metrics overhead gate + trace validation
  roofline          -- dry-run roofline table (assignment)

Modules that maintain a committed ``BENCH_*.json`` artifact also print one
``<name>,artifact,<summary>`` line (via their ``artifact_summary`` hook),
so the perf trajectory across PRs is greppable straight from the driver
output (``grep ',artifact,'``).
"""

import argparse
import os
import sys
import traceback

# Forced host devices so shard_bench's mesh sweep works under the driver;
# must land before the first benchmark module imports jax (harmless for
# the others, and on real accelerators the flag only affects the host
# platform).
_FORCE = "--xla_force_host_platform_device_count"
if "jax" not in sys.modules and _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=8").strip()

MODULES = [
    "table1_gates", "fig5_throughput", "fig6_breakdown", "fig7_patlen",
    "fig8_tech", "fig9_10_nmp", "fig11_gates", "table4_apps",
    "sec5_5_variation", "kernel_bench", "service_bench", "query_bench",
    "ingest_bench", "filter_bench", "standing_bench", "shard_bench",
    "calibrate_bench", "obs_bench",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES

    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us},{derived}")
            summary = getattr(mod, "artifact_summary", None)
            if summary is not None:
                line = summary()
                if line:
                    # A module may brand its artifact line (obs_bench
                    # prints as ``obs,artifact,...``).
                    label = getattr(mod, "SUMMARY_NAME", name)
                    print(f"{label},artifact,{line}")
        except Exception:
            failures += 1
            print(f"{name},ERROR,{traceback.format_exc(limit=1).splitlines()[-1]}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
