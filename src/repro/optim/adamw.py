"""AdamW + warmup-cosine schedule + global-norm clipping (pure JAX).

Functional: ``init`` builds the (m, v, step) state with the same sharding
axes as the parameters (the state pytree mirrors params), ``update`` returns
new (params, state).  Optional gradient compression (bf16 cast before the
cross-replica reduction) is applied in the train step, not here.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_compression: str = "none"      # none | bf16 | int8


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.peak_lr * jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(cfg: OptConfig, grads, state, params) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step.astype(jnp.float32))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics


def compress(cfg: OptConfig, grads):
    """Gradient-compression hook applied before the cross-replica reduce.

    bf16: plain down-cast (2x wire traffic reduction).  int8: per-leaf
    symmetric quantization with f32 scale (error feedback handled by Adam's
    v-normalization; good enough for DP all-reduce traffic studies)."""
    if cfg.grad_compression == "none":
        return grads
    if cfg.grad_compression == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    if cfg.grad_compression == "int8":
        def q(g):
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-9) / 127.0
            return (jnp.round(g / scale).astype(jnp.int8), scale)
        return jax.tree.map(q, grads)
    raise ValueError(cfg.grad_compression)


def decompress(cfg: OptConfig, grads):
    if cfg.grad_compression == "int8":
        return jax.tree.map(
            lambda t: t[0].astype(jnp.float32) * t[1], grads,
            is_leaf=lambda x: isinstance(x, tuple))
    return grads
