import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration driver (EXPERIMENTS §Perf hillclimb).

Lowers one (arch x shape) cell with config overrides, re-derives the
roofline terms, and appends a tagged record -- the measure step of each
hypothesis -> change -> measure -> validate cycle.

  PYTHONPATH=src python -m benchmarks.perf_iter --arch mamba2-130m \
      --shape train_4k --tag bf16_intra --set ssd_bf16_intra=True
"""

import argparse
import dataclasses
import json
import pathlib

from repro.configs import get_config
from repro.launch.dryrun import lower_cell
from repro.models.config import SHAPES


def parse_value(v: str):
    if v in ("True", "False"):
        return v == "True"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", nargs="*", default=[],
                    help="config overrides key=value")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="experiments/perf/iters.jsonl")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    rec = lower_cell(args.arch, args.shape, args.multipod, cfg=cfg)
    rec["tag"] = args.tag
    rec["overrides"] = overrides
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    with out.open("a") as f:
        f.write(json.dumps(rec) + "\n")
    keys = ("tag", "status", "compute_s", "memory_s", "collective_s",
            "dominant", "compile_s")
    print(json.dumps({k: rec.get(k) for k in keys}))
    if rec.get("status") == "ok":
        bound = max(rec["compute_s"], rec["memory_s"], rec["collective_s"])
        mfu = rec["model_flops_global"] / rec["n_devices"] / 197e12 / bound
        print(f"bound={bound:.4g}s mfu_bound={mfu:.4f} "
              f"coll={{{', '.join(f'{k}:{v/1e9:.1f}GB' for k, v in rec['collectives'].items() if v)}}}")


if __name__ == "__main__":
    main()
