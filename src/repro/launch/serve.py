"""Serving launcher: batched requests through the Engine.

``python -m repro.launch.serve --arch llama3.2-1b --smoke`` boots a
randomly initialized reduced model, runs a batch of synthetic requests
through the continuous-batching engine, and reports decode throughput +
n-gram speculator acceptance (the paper's matcher in the serving plane).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import model
from repro.serving.engine import Engine, Request
from repro.serving.ngram_cache import NgramSpeculator, verify


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                        dtype=np.int32),
                    max_new=args.max_new)
            for _ in range(args.requests)]
    eng = Engine(cfg, params, max_seq=args.max_seq, n_slots=args.slots)
    t0 = time.perf_counter()
    eng.run(list(reqs))
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")

    # n-gram speculation demo on the generated streams
    spec = NgramSpeculator()
    acc, tries = 0, 0
    for r in reqs:
        spec.feed(r.out)
    for r in reqs:
        if len(r.out) > 8:
            prop, conf = spec.propose(r.out[:4], k=4)
            acc += verify(prop, np.asarray(r.out[4:8]))
            tries += 4
    if tries:
        print(f"ngram speculator acceptance: {acc}/{tries}")


if __name__ == "__main__":
    main()
