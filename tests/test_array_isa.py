"""Array interpreter + ISA codegen tests (paper Secs. 2.4, 3.3)."""

import numpy as np
import pytest

from repro.core.array import CRAMArray, MicroOp, Program, run_program
from repro.core.isa import CodeGen, ColumnAllocator

import jax.numpy as jnp


def make_cg(n_cols=256, lo=0, opt=False):
    return CodeGen(ColumnAllocator(lo, n_cols), opt=opt)


class TestInterpreter:
    def test_row_parallelism(self):
        """One micro-op applies to the same columns of every row at once."""
        arr = CRAMArray(8, 16)
        data = np.random.default_rng(0).integers(0, 2, (8, 2), np.uint8)
        arr.write_column_rows(0, data)
        prog = Program([MicroOp("PRESET0", (), 5), MicroOp("NOR", (0, 1), 5)])
        arr.run(prog)
        got = np.asarray(arr.state[:, 5])
        want = 1 - (data[:, 0] | data[:, 1])
        np.testing.assert_array_equal(got, want)

    def test_preset_values(self):
        arr = CRAMArray(4, 8)
        arr.run(Program([MicroOp("PRESET1", (), 3), MicroOp("PRESET0", (), 2)]))
        assert np.asarray(arr.state[:, 3]).tolist() == [1, 1, 1, 1]
        assert np.asarray(arr.state[:, 2]).tolist() == [0, 0, 0, 0]

    def test_output_usable_as_input(self):
        """Sec. 2.6: an output cell serves as an input in later steps."""
        state = jnp.zeros((2, 8), jnp.uint8).at[:, 0].set(jnp.array([0, 1], jnp.uint8))
        prog = Program([
            MicroOp("PRESET0", (), 4), MicroOp("INV", (0,), 4),   # c4 = !c0
            MicroOp("PRESET0", (), 5), MicroOp("INV", (4,), 5),   # c5 = c0
        ])
        out = run_program(state, prog)
        np.testing.assert_array_equal(np.asarray(out[:, 5]), np.array([0, 1]))

    def test_all_gates_on_array(self):
        rng = np.random.default_rng(1)
        v = rng.integers(0, 2, (32, 5), np.uint8)
        arr = CRAMArray(32, 16)
        arr.write_column_rows(0, v)
        cases = {
            "NOR": 1 - (v[:, 0] | v[:, 1]),
            "OR": v[:, 0] | v[:, 1],
            "NAND": 1 - (v[:, 0] & v[:, 1]),
            "AND": v[:, 0] & v[:, 1],
            "INV": 1 - v[:, 0],
            "COPY": v[:, 0],
            "MAJ3": (v[:, :3].sum(1) >= 2).astype(np.uint8),
            "MAJ5": (v.sum(1) >= 3).astype(np.uint8),
            "TH": (v[:, :4].sum(1) <= 1).astype(np.uint8),
        }
        from repro.core.array import ARITY
        for op, want in cases.items():
            prog = Program([
                MicroOp("PRESET0", (), 10),
                MicroOp(op, tuple(range(ARITY[op])), 10),
            ])
            arr.run(prog)
            np.testing.assert_array_equal(np.asarray(arr.state[:, 10]), want, op)

    def test_memory_stats_tracking(self):
        arr = CRAMArray(4, 16)
        arr.write_row(0, 0, [1, 0, 1])
        arr.read_row(0, 0, 3)
        assert arr.mem_stats["row_writes"] == 1
        assert arr.mem_stats["bits_written"] == 3
        assert arr.mem_stats["row_reads"] == 1


class TestCodeGen:
    def run_rows(self, cg, inputs):
        """Execute the emitted program with given input column values."""
        n_rows = inputs.shape[0]
        arr = CRAMArray(n_rows, cg.scratch.hi)
        arr.write_column_rows(0, inputs)
        arr.run(cg.prog)
        return arr

    def test_xor(self):
        inputs = np.array([[0, 0], [0, 1], [1, 0], [1, 1]], np.uint8)
        cg = make_cg(lo=2)
        out = cg.xor(0, 1)
        arr = self.run_rows(cg, inputs)
        np.testing.assert_array_equal(
            np.asarray(arr.state[:, out]), np.array([0, 1, 1, 0]))

    def test_full_adder_all_inputs(self):
        inputs = np.array(
            [[a, b, c] for a in (0, 1) for b in (0, 1) for c in (0, 1)],
            np.uint8)
        cg = make_cg(lo=3)
        s, cout = cg.full_adder(0, 1, 2)
        arr = self.run_rows(cg, inputs)
        total = inputs.sum(1)
        np.testing.assert_array_equal(np.asarray(arr.state[:, s]), total & 1)
        np.testing.assert_array_equal(np.asarray(arr.state[:, cout]), total >> 1)

    def test_full_adder_is_four_gates(self):
        """Fig. 2: the MAJ-based FA is exactly 4 logic steps."""
        cg = make_cg(lo=3)
        cg.full_adder(0, 1, 2)
        assert cg.prog.n_logic_ops() == 4
        counts = cg.prog.op_counts()
        assert counts["MAJ3"] == 1 and counts["MAJ5"] == 1
        assert counts["INV"] == 1 and counts["COPY"] == 1

    @pytest.mark.parametrize("n_bits", [1, 2, 3, 5, 8, 16, 33, 100])
    def test_popcount_tree(self, n_bits):
        rng = np.random.default_rng(n_bits)
        data = rng.integers(0, 2, (16, n_bits), np.uint8)
        cg = make_cg(n_cols=max(256, 6 * n_bits + 64), lo=n_bits)
        score_cols = cg.popcount_tree(list(range(n_bits)))
        arr = self.run_rows(cg, data)
        weights = 1 << np.arange(len(score_cols))
        got = (np.stack([np.asarray(arr.state[:, c]) for c in score_cols], -1)
               * weights).sum(-1)
        np.testing.assert_array_equal(got, data.sum(1))

    def test_popcount_score_width(self):
        """Paper Sec. 3.2: N = floor(log2 len) + 1 bits."""
        cg = make_cg(n_cols=1024, lo=100)
        cols = cg.popcount_tree(list(range(100)))
        assert len(cols) == 7

    def test_popcount_fa_count_matches_paper(self):
        """Paper: ~188 1-bit additions for a 100-bit match string."""
        cg = make_cg(n_cols=1024, lo=100)
        cg.popcount_tree(list(range(100)))
        assert 180 <= cg.fa_count() <= 200

    def test_char_match(self):
        """Fig. 4a: 2-bit compare -> 1 iff characters equal."""
        cases = []
        for fa in range(4):
            for pa in range(4):
                cases.append([fa & 1, fa >> 1, pa & 1, pa >> 1])
        inputs = np.array(cases, np.uint8)
        cg = make_cg(lo=4)
        out = cg.char_match(0, 1, 2, 3)
        arr = self.run_rows(cg, inputs)
        want = np.array([1 if i // 4 == i % 4 else 0 for i in range(16)])
        np.testing.assert_array_equal(np.asarray(arr.state[:, out]), want)

    def test_every_gate_preceded_by_its_preset(self):
        """Invariant: each logic op's output column was preset to the gate's
        required value more recently than any earlier write to it."""
        from repro.core.isa import PRESET_FOR
        cg = make_cg(lo=3)
        cg.char_match(0, 1, 2, 3) if False else None
        cg.full_adder(0, 1, 2)
        cg.xor(0, 1)
        last_preset = {}
        for op in cg.prog:
            if op.op.startswith("PRESET"):
                last_preset[op.out] = int(op.op[-1])
            else:
                assert last_preset.get(op.out) == PRESET_FOR[op.op], op

    def test_scratch_reuse_is_safe(self):
        """Released columns may be recycled; presets make reuse safe."""
        rng = np.random.default_rng(3)
        data = rng.integers(0, 2, (8, 6), np.uint8)
        cg = make_cg(n_cols=64, lo=6)
        o1 = cg.xor(0, 1)
        o2 = cg.xor(2, 3)   # reuses released scratch from o1
        o3 = cg.xor(4, 5)
        arr = self.run_rows(cg, data)
        np.testing.assert_array_equal(
            np.asarray(arr.state[:, o1]), data[:, 0] ^ data[:, 1])
        np.testing.assert_array_equal(
            np.asarray(arr.state[:, o2]), data[:, 2] ^ data[:, 3])
        np.testing.assert_array_equal(
            np.asarray(arr.state[:, o3]), data[:, 4] ^ data[:, 5])

    def test_allocator_overflow_raises(self):
        alloc = ColumnAllocator(0, 4)
        alloc.alloc(4)
        with pytest.raises(RuntimeError):
            alloc.alloc(1)

    def test_allocator_reuse_floor(self):
        alloc = ColumnAllocator(10, 20, reuse_lo=5)
        alloc.release([3, 7])      # 3 below reuse floor -> ignored
        assert alloc.alloc(1) == [7]
        assert alloc.alloc(1) == [10]
