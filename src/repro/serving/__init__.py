"""repro.serving"""
