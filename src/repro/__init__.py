"""CRAM-PM reproduction framework.

Layers: core (paper functional+cost reproduction) / kernels (TPU-adapted
Pallas) / models + configs (assigned architecture pool) / distributed +
launch (multi-pod pjit) / optim + checkpoint + data + runtime + serving
(production substrate).  See DESIGN.md.
"""

__version__ = "1.0.0"
