"""Data pipeline: deterministic-seek token streams + host sharding.

Restart discipline (fault tolerance): every batch is a pure function of
(seed, step) -- ``batch_at(step)`` -- so a job restarted from a checkpoint
at step N replays the identical remaining stream with zero coordination.
Host sharding takes the data-axis slice of the global batch, matching the
``batch -> (pod, data)`` sharding rule.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Seeded synthetic next-token stream (Zipfian tokens with local
    structure so the loss visibly decreases)."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        B, S = self.global_batch, self.seq_len
        # Zipf-ish marginal + repeated bigram structure (learnable signal).
        base = rng.zipf(1.3, size=(B, S + 1)) % self.vocab
        rep = rng.integers(0, self.vocab, (B, 1))
        mask = rng.random((B, S + 1)) < 0.3
        toks = np.where(mask, rep, base).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class TextLM:
    """Byte-level LM over an in-memory corpus with deterministic seek."""

    corpus: bytes
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        B, S = self.global_batch, self.seq_len
        n = len(self.corpus) - S - 1
        starts = rng.integers(0, max(n, 1), B)
        toks = np.stack([np.frombuffer(
            self.corpus[s:s + S + 1], np.uint8).astype(np.int32)
            for s in starts])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def host_shard(batch: Dict[str, np.ndarray], host_index: int,
               n_hosts: int) -> Dict[str, np.ndarray]:
    """This host's slice of the global batch (data-axis sharding)."""
    def sl(x):
        b = x.shape[0]
        per = b // n_hosts
        return x[host_index * per:(host_index + 1) * per]
    return {k: sl(v) for k, v in batch.items()}
