"""Pure-jnp oracles for the CRAM-PM TPU kernels.

Every Pallas kernel in this package has its semantics defined here first;
``tests/test_kernels_*.py`` sweep shapes/dtypes asserting bit-exact (integer
paths) or allclose (bf16 MXU path) agreement in ``interpret=True`` mode.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

M2 = np.uint32(0x33333333)
M4 = np.uint32(0x0F0F0F0F)
M1 = np.uint32(0x55555555)
MUL = np.uint32(0x01010101)


def popcount_u32(v: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount of a uint32 array (branch-free, VPU-friendly)."""
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & M1)
    v = (v & M2) + ((v >> 2) & M2)
    v = (v + (v >> 4)) & M4
    return ((v * MUL) >> 24).astype(jnp.int32)


def popcount_ref(words: jnp.ndarray) -> jnp.ndarray:
    """(N, W) uint32 -> (N,) int32 total popcount per row (BC benchmark)."""
    return popcount_u32(words).sum(axis=-1, dtype=jnp.int32)


BITWISE_OPS = ("NOT", "OR", "NAND", "XOR", "AND", "NOR")


def bitwise_ref(op: str, a: jnp.ndarray, b: jnp.ndarray | None = None) -> jnp.ndarray:
    a = a.astype(jnp.uint32)
    if op == "NOT":
        return ~a
    b = b.astype(jnp.uint32)
    if op == "OR":
        return a | b
    if op == "AND":
        return a & b
    if op == "NAND":
        return ~(a & b)
    if op == "NOR":
        return ~(a | b)
    if op == "XOR":
        return a ^ b
    raise ValueError(op)


def match_scores_ref(fragments: jnp.ndarray, patterns: jnp.ndarray) -> jnp.ndarray:
    """Character-level sliding similarity scores (Algorithm 1 semantics).

    fragments: (R, F) uint8 codes; patterns: (P,) or (R, P).
    Returns (R, F-P+1) int32: number of character matches per alignment.
    """
    fragments = jnp.asarray(fragments)
    patterns = jnp.asarray(patterns)
    if patterns.ndim == 1:
        patterns = jnp.broadcast_to(patterns, (fragments.shape[0], patterns.shape[0]))
    R, F = fragments.shape
    P = patterns.shape[1]
    L = F - P + 1
    cols = []
    for o in range(L):
        cols.append((fragments[:, o:o + P] == patterns).sum(-1, dtype=jnp.int32))
    return jnp.stack(cols, axis=1)


def match_scores_masks_ref(fragments: jnp.ndarray,
                           masks: jnp.ndarray) -> jnp.ndarray:
    """Accept-set sliding scores (predicate semantics, Sec. 3e).

    fragments: (R, F) uint8 codes; masks: (P,) or (R, P) uint8 accept
    masks -- bit c of position i set iff code c matches there.  Returns
    (R, F-P+1) int32: number of accepted positions per alignment.  With
    one-hot masks this is exactly ``match_scores_ref``.
    """
    fragments = jnp.asarray(fragments)
    masks = jnp.asarray(masks, jnp.uint8)
    if masks.ndim == 1:
        masks = jnp.broadcast_to(masks, (fragments.shape[0], masks.shape[0]))
    R, F = fragments.shape
    P = masks.shape[1]
    L = F - P + 1
    cols = []
    for o in range(L):
        hit = (masks >> fragments[:, o:o + P]) & jnp.uint8(1)
        cols.append(hit.sum(-1, dtype=jnp.int32))
    return jnp.stack(cols, axis=1)


def match_scores_swar_ref(ref_words: jnp.ndarray, pat_words: jnp.ndarray,
                          valid_mask: jnp.ndarray, n_locs: int,
                          pattern_chars: int) -> jnp.ndarray:
    """jnp mirror of the SWAR kernel's packed semantics.

    ref_words: (R, W) uint32, 16 2-bit chars/word, padded with >=1 zero word
    beyond the last alignment's reach.  pat_words: (R, Wp) uint32.
    valid_mask: (Wp,) uint32 -- low bit of each valid char lane set.
    """
    ref_words = ref_words.astype(jnp.uint32)
    pat_words = pat_words.astype(jnp.uint32)
    R, W = ref_words.shape
    Wp = pat_words.shape[1]
    out = []
    for loc in range(n_locs):
        base, sh = divmod(loc, 16)
        r = np.uint32(2 * sh)
        seg = ref_words[:, base:base + Wp + 1]
        lo = seg[:, :Wp] >> r
        if sh == 0:
            window = lo
        else:
            window = lo | (seg[:, 1:] << np.uint32(32 - 2 * sh))
        diff = window ^ pat_words
        mism = (diff | (diff >> np.uint32(1))) & M1 & valid_mask[None, :]
        # mism has at most one bit per 2-bit lane -> start SWAR at stage 2.
        v = (mism & M2) + ((mism >> 2) & M2)
        v = (v + (v >> 4)) & M4
        mismatches = ((v * MUL) >> 24).astype(jnp.int32).sum(-1)
        out.append(pattern_chars - mismatches)
    return jnp.stack(out, axis=1)


def onehot_scores_ref(fragments: jnp.ndarray, patterns: jnp.ndarray) -> jnp.ndarray:
    """Batched-pattern scores via one-hot contraction (MXU formulation).

    fragments: (R, F) uint8; patterns: (Q, P) uint8.
    Returns (R, L, Q) int32 -- score of pattern q aligned at loc o of row r.
    """
    fragments = jnp.asarray(fragments)
    patterns = jnp.asarray(patterns)
    R, F = fragments.shape
    Q, P = patterns.shape
    L = F - P + 1
    f1h = jax_one_hot(fragments, 4)          # (R, F, 4)
    p1h = jax_one_hot(patterns, 4)           # (Q, P, 4)
    out = []
    for o in range(L):
        win = f1h[:, o:o + P, :].reshape(R, P * 4)
        out.append(win @ p1h.reshape(Q, P * 4).T)
    return jnp.stack(out, axis=1).astype(jnp.int32)


def jax_one_hot(x: jnp.ndarray, n: int, dtype=jnp.float32) -> jnp.ndarray:
    return (x[..., None] == jnp.arange(n, dtype=x.dtype)).astype(dtype)
