"""Paper Fig. 5: throughput + energy efficiency, Naive/Oracular x plain/Opt,
3M-pattern DNA pool, normalized to the GPU baseline.

Alongside the analytic substrate model, a scaled-down *measured* run goes
through the match engine (device-resident corpus, warm query path) so the
figure carries a real TPU-adaptation data point next to the projections.
"""

import time

import numpy as np

from repro.core import costmodel as cm
from repro.core import encoding
from repro.core.tech import NEAR_TERM

PAPER = {("naive", False): 23215.3, ("oracular", False): 2.32}

# Measured engine slice: small genome, warm repeated queries.
MR_GENOME, MR_FRAG, MR_PAT, MR_READS = 20_000, 500, 100, 8


def _engine_measured():
    from repro.match import MatchEngine, PackedCorpus

    rng = np.random.default_rng(5)
    genome = encoding.random_dna(rng, MR_GENOME)
    corpus = PackedCorpus.from_reference(genome, MR_FRAG, MR_PAT)
    eng = MatchEngine(corpus)
    reads = rng.integers(0, 4, (MR_READS, MR_PAT), np.uint8)
    eng.match(reads[0], backend="swar", reduction="best")   # warm-up + pack
    t0 = time.perf_counter()
    for r in reads:
        eng.match(r, backend="swar", reduction="best")
    dt = (time.perf_counter() - t0) / MR_READS
    return corpus.n_rows, dt, corpus.host_pack_count


def run():
    rows = []
    gpu = cm.GPUBaseline()
    for opt in (False, True):
        for sched in ("naive", "oracular"):
            t0 = time.perf_counter()
            d = cm.Design(tech=NEAR_TERM, opt=opt)
            r = cm.run_workload(d, 3_000_000, sched)
            us = (time.perf_counter() - t0) * 1e6
            name = f"fig5/{sched}{'Opt' if opt else ''}"
            paper_h = PAPER.get((sched, opt))
            rows.append((name, round(us, 1),
                         f"hours={r.total_time_s/3600:.2f}"
                         + (f" paper={paper_h}" if paper_h else "")
                         + f" rate={r.match_rate:.4g}/s"
                         f" vs_gpu={r.match_rate/gpu.match_rate:.3g}x"
                         f" eff={r.efficiency:.4g}/s/mW"
                         f" eff_vs_gpu={r.efficiency/gpu.efficiency:.3g}x"))
    n_rows, per_read_s, packs = _engine_measured()
    rows.append(("fig5/engine_measured", round(per_read_s * 1e6, 1),
                 f"reads_per_s={1.0/per_read_s:.4g} rows={n_rows}"
                 f" packs={packs} (warm resident-corpus path,"
                 " interpret-mode slice)"))
    return rows
