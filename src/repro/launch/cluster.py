"""Multi-host cluster bootstrap (1000+ node path).

On a real TPU/TRN fleet every host runs the same entry point; this module
derives (coordinator, process_id, process_count) from the scheduler
environment (TPU metadata, SLURM, or explicit REPRO_* variables), calls
``jax.distributed.initialize``, and returns the host's role.  The rest of
the stack is already multi-host-clean:

* ``make_production_mesh`` builds from ``jax.devices()`` (global after
  initialize);
* ``data.pipeline.host_shard`` slices the deterministic batch stream by
  (process_id, process_count) -- restarts replay identically on any host
  count;
* ``checkpoint.CheckpointManager`` restores onto any mesh (elastic), so a
  job rescheduled from 2 pods to 1 resumes from the same step;
* the straggler watchdog (runtime/loop.py) triggers the snapshot +
  drop-and-reshard path on slow hosts.

Typical driver::

    from repro.launch import cluster
    info = cluster.initialize()           # no-op on a single host
    mesh = make_production_mesh(multi_pod=info.process_count > 1)

The module doubles as a runnable multi-controller proof (DESIGN.md
Sec. 3k): ``python -m repro.launch.cluster --demo`` spawns a 2-process
CPU ``jax.distributed`` job (4 forced host devices each -> the same
8-shard mesh a single process gets) plus a 1-process 8-shard baseline,
runs the full match workload -- threshold / forced-filter / IUPAC
wildcard / top-k / best, then ``append_rows`` growth, tombstoning, and
``compact()`` -- in every process, and asserts the results are
bit-identical across the two layouts with flat per-host pack counters.
"""

from __future__ import annotations

import dataclasses
import json
import os
import socket
import subprocess
import sys
import tempfile
from pathlib import Path
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class HostInfo:
    coordinator: Optional[str]
    process_id: int
    process_count: int

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0


def detect_environment(env=None) -> HostInfo:
    """Resolve the host's role from the environment (no side effects).

    Priority: explicit REPRO_* vars > SLURM > single host.
    """
    env = env if env is not None else os.environ
    if "REPRO_COORDINATOR" in env:
        return HostInfo(
            coordinator=env["REPRO_COORDINATOR"],
            process_id=int(env.get("REPRO_PROCESS_ID", "0")),
            process_count=int(env.get("REPRO_NUM_PROCESSES", "1")),
        )
    if "SLURM_JOB_NUM_NODES" in env and int(env["SLURM_JOB_NUM_NODES"]) > 1:
        nodelist = env.get("SLURM_STEP_NODELIST", env.get("SLURM_NODELIST", ""))
        first = _first_slurm_node(nodelist)
        port = env.get("REPRO_PORT", "8476")
        return HostInfo(
            coordinator=f"{first}:{port}" if first else None,
            process_id=int(env.get("SLURM_PROCID", "0")),
            process_count=int(env["SLURM_JOB_NUM_NODES"]),
        )
    return HostInfo(coordinator=None, process_id=0, process_count=1)


def _first_slurm_node(nodelist: str) -> Optional[str]:
    """First hostname of a SLURM nodelist ('a[001-004],b02' -> 'a001')."""
    if not nodelist:
        return None
    head = nodelist.split(",")[0]
    if "[" not in head:
        return head
    prefix, rng = head.split("[", 1)
    rng = rng.rstrip("]")
    first = rng.split(",")[0].split("-")[0]
    return prefix + first


def initialize(info: Optional[HostInfo] = None) -> HostInfo:
    """Call jax.distributed.initialize when running multi-host; no-op on a
    single host (this container)."""
    info = info or detect_environment()
    if info.process_count > 1 and info.coordinator:
        import jax
        if "cpu" in os.environ.get("JAX_PLATFORMS", ""):
            # CPU multi-controller needs the gloo collectives backend;
            # the default CPU client refuses cross-process collectives.
            # Must be set before jax.distributed.initialize.
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(
            coordinator_address=info.coordinator,
            num_processes=info.process_count,
            process_id=info.process_id,
        )
        try:
            # Non-shard_map ops over globally-sharded arrays (jitted
            # splices with replicated operands) are SPMD-legal here;
            # older jax versions gate them behind spmd_mode.
            jax.config.update("jax_spmd_mode", "allow_all")
        except Exception:
            pass
    return info


# -- multi-process CPU demo (DESIGN.md Sec. 3k bit-identity gate) ------------

def cpu_process_env(process_id: int, num_processes: int, coordinator: str,
                    local_devices: int = 4) -> Dict[str, str]:
    """Environment overrides for one CPU process of a local multi-
    controller job: ``local_devices`` forced host devices per process,
    role wired through the REPRO_* variables ``detect_environment``
    reads."""
    return {
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": f"--xla_force_host_platform_device_count="
                     f"{int(local_devices)}",
        "REPRO_COORDINATOR": coordinator,
        "REPRO_PROCESS_ID": str(int(process_id)),
        "REPRO_NUM_PROCESSES": str(int(num_processes)),
    }


def _demo_workload() -> dict:
    """The deterministic match workload every demo process runs.

    Same seed, same queries, same mutation sequence in every process --
    the SPMD contract.  Returns a JSON-serializable dict of results
    (reduced outputs only; exactly what crosses the merge layer to the
    host) plus the corpus pack counters, so layouts can be compared
    bit-for-bit.
    """
    import jax
    import numpy as np

    from ..match.corpus import PackedCorpus
    from ..match.engine import MatchEngine
    from ..match.query import MatchQuery
    from .mesh import make_row_mesh

    n_dev = len(jax.devices())
    mesh = make_row_mesh(n_dev) if n_dev > 1 else None
    rng = np.random.default_rng(7)
    frags = rng.integers(0, 4, size=(1024, 64)).astype(np.uint8)
    pattern = np.array(frags[11, 10:42])          # 32-char planted needle
    planted = [3, 500, 1021]
    for r in planted:
        frags[r, 5:37] = pattern
    corpus = PackedCorpus(frags, capacity=2048)
    # record_runtimes off even single-process: feedback re-pricing could
    # flip a later plan in the baseline but not the (always-off)
    # multi-controller run, breaking the apples-to-apples comparison.
    engine = MatchEngine(corpus, mesh=mesh, record_runtimes=False)

    iupac = "".join("ACGT"[c] for c in pattern)
    iupac = iupac[:2] + "N" + iupac[3:17] + "N" + iupac[18:]
    thr = float(pattern.size)
    queries = {
        "threshold_scan": MatchQuery.exact(
            pattern, reduction="threshold", threshold=thr, filter=False),
        "threshold_filtered": MatchQuery.exact(
            pattern, reduction="threshold", threshold=thr, filter=True),
        "iupac_wildcard": MatchQuery.iupac(
            iupac, reduction="threshold", threshold=thr),
        "topk": MatchQuery.exact(pattern, reduction="topk", k=9),
        "best": MatchQuery.exact(pattern),
    }
    compiled = {name: engine.compile(q) for name, q in queries.items()}

    def snap(res) -> dict:
        out = {
            "merge_path": res.merge_path,
            "collective_bytes": int(res.collective_bytes),
            "n_shards": int(res.n_shards),
            "backend": res.plan.backend,
            "strategy": res.plan.strategy,
            "best_locs": np.asarray(res.best_locs).tolist(),
            "best_scores": np.asarray(res.best_scores).tolist(),
        }
        if res.hits is not None:
            out["hits"] = np.asarray(res.hits).tolist()
        if res.topk_rows is not None:
            out["topk_rows"] = np.asarray(res.topk_rows).tolist()
            out["topk_scores"] = np.asarray(res.topk_scores).tolist()
        if res.survivor_rows is not None:
            out["n_survivors"] = int(np.asarray(res.survivor_rows).size)
        return out

    results = {name: snap(c.run()) for name, c in compiled.items()}
    base_expect = {(3, 5), (500, 5), (1021, 5), (11, 10)}
    for stage in ("threshold_scan", "threshold_filtered"):
        got0 = {(int(r), int(l)) for r, l, _ in results[stage]["hits"]}
        if base_expect - got0:
            raise AssertionError(
                f"{stage}: planted rows missing: "
                f"{sorted(base_expect - got0)} (got {sorted(got0)})")

    # Growth: 96 appended rows with the needle planted in one of them
    # (logical row 1024 + 40); the splice must land it on the right
    # shard under the cyclic layout in every process.
    extra = np.random.default_rng(11).integers(
        0, 4, size=(96, 64)).astype(np.uint8)
    extra[40, 20:52] = pattern
    corpus.append_rows(extra)
    results["threshold_after_append"] = snap(compiled["threshold_scan"].run())
    results["topk_after_append"] = snap(compiled["topk"].run())

    # Eviction: tombstone two planted rows (their hits must vanish),
    # then compact (ids above the dead rows shift down by two).
    corpus.tombstone([3, 500])
    results["threshold_after_tombstone"] = snap(
        compiled["threshold_scan"].run())
    corpus.compact()
    results["threshold_after_compact"] = snap(
        compiled["threshold_scan"].run())
    results["best_after_compact"] = snap(compiled["best"].run())

    # Zero-false-negative gate, independent of any cross-layout diff:
    # every surviving planted row must report an exact-score hit.
    expect = {(11 - 1, 10), (1021 - 2, 5), (1024 + 40 - 2, 20)}
    got = {(int(r), int(l)) for r, l, _ in
           results["threshold_after_compact"]["hits"]}
    missing = expect - got
    if missing:
        raise AssertionError(
            f"planted rows missing from threshold hits: {sorted(missing)} "
            f"(got {sorted(got)})")

    return {
        "process_count": jax.process_count(),
        "process_id": jax.process_index(),
        "n_devices": n_dev,
        "n_shards": engine._row_shards,
        "merge_path": engine.merger.merge_path,
        "collective_bytes": int(engine.merger.collective_bytes),
        "n_collectives": int(engine.merger.n_collectives),
        "pack_counts": {
            "swar": corpus.swar_pack_count,
            "onehot": corpus.onehot_pack_count,
            "host_total": corpus.host_pack_count,
            "row_updates": corpus.row_update_count,
        },
        "results": results,
    }


def _worker_main() -> None:
    """Entry point for one demo process (spawned by ``run_cpu_demo``)."""
    info = initialize()
    summary = _demo_workload()
    out = os.environ.get("REPRO_DEMO_OUT")
    if out:
        with open(out, "w") as fh:
            json.dump(summary, fh, indent=2, sort_keys=True)
    if info.is_coordinator:
        print(json.dumps({k: summary[k] for k in
                          ("process_count", "n_shards", "merge_path",
                           "collective_bytes", "pack_counts")}))


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _spawn_worker(env_over: Dict[str, str], out_path: str,
                  extra_env: Optional[Dict[str, str]] = None):
    env = dict(os.environ)
    for k in ("REPRO_COORDINATOR", "REPRO_PROCESS_ID",
              "REPRO_NUM_PROCESSES", "REPRO_DEMO_OUT"):
        env.pop(k, None)
    src = str(Path(__file__).resolve().parents[2])
    pp = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
    env.update(env_over)
    env["REPRO_DEMO_OUT"] = out_path
    if extra_env:
        env.update(extra_env)
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.cluster", "--worker"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)


def run_cpu_demo(n_processes: int = 2, local_devices: int = 4,
                 timeout: float = 600.0) -> dict:
    """Run the bit-identity gate: ``n_processes`` CPU controllers
    (``local_devices`` forced host devices each) vs a single process
    with the same global device count, same 8-shard mesh.

    Returns a summary dict with per-layout results and the list of
    mismatching stages (empty == gate passed).  Raises RuntimeError if
    any worker exits non-zero.
    """
    port = _free_port()
    coord = f"127.0.0.1:{port}"
    tmp = tempfile.mkdtemp(prefix="repro_mh_demo_")
    outs = [os.path.join(tmp, f"proc{i}.json") for i in range(n_processes)]
    base_out = os.path.join(tmp, "single.json")
    procs = [
        _spawn_worker(cpu_process_env(i, n_processes, coord, local_devices),
                      outs[i])
        for i in range(n_processes)
    ]
    # Single-process baseline: same global device count, no distributed
    # init (REPRO_COORDINATOR unset -> process_count == 1).
    procs.append(_spawn_worker(
        {"JAX_PLATFORMS": "cpu",
         "XLA_FLAGS": f"--xla_force_host_platform_device_count="
                      f"{n_processes * local_devices}"},
        base_out))
    failures: List[str] = []
    for i, p in enumerate(procs):
        try:
            stdout, stderr = p.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise RuntimeError(
                f"demo worker {i} timed out after {timeout}s")
        if p.returncode != 0:
            tag = "baseline" if i == n_processes else f"proc{i}"
            failures.append(
                f"[{tag}] exit {p.returncode}\n{stderr[-4000:]}")
    if failures:
        raise RuntimeError("demo workers failed:\n" + "\n".join(failures))
    multi = [json.load(open(o)) for o in outs]
    single = json.load(open(base_out))

    mismatches: List[str] = []
    for i in range(1, n_processes):
        if multi[i]["results"] != multi[0]["results"]:
            mismatches.append(f"proc{i} diverged from proc0 (SPMD break)")

    def strip(stage: dict) -> dict:
        # Byte accounting legitimately depends on the controller
        # topology (a single controller addresses every shard directly;
        # a multi-controller gather is a collective) -- compare the
        # *results*, not the transfer ledger.
        return {k: v for k, v in stage.items() if k != "collective_bytes"}

    for stage in single["results"]:
        if (strip(multi[0]["results"].get(stage, {}))
                != strip(single["results"][stage])):
            mismatches.append(stage)
    if single["pack_counts"] != multi[0]["pack_counts"]:
        mismatches.append(
            f"pack_counts: single={single['pack_counts']} "
            f"multi={multi[0]['pack_counts']}")
    return {
        "identical": not mismatches,
        "mismatches": mismatches,
        "n_processes": n_processes,
        "local_devices": local_devices,
        "n_shards": multi[0]["n_shards"],
        "multiprocess": multi,
        "single": single,
    }


def _main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--worker", action="store_true",
                    help="run one demo process (internal; spawned by "
                         "--demo)")
    ap.add_argument("--demo", action="store_true",
                    help="run the 2-process CPU bit-identity demo")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--local-devices", type=int, default=4)
    args = ap.parse_args(argv)
    if args.worker:
        _worker_main()
        return 0
    if args.demo:
        summary = run_cpu_demo(args.processes, args.local_devices)
        print(json.dumps(
            {k: summary[k] for k in ("identical", "mismatches",
                                     "n_processes", "n_shards")},
            indent=2))
        return 0 if summary["identical"] else 1
    ap.print_help()
    return 2


if __name__ == "__main__":
    raise SystemExit(_main())
