"""Checkpoint manager: atomic, mesh-elastic, async, auto-resuming.

Fault-tolerance contract (DESIGN.md Sec. 5):

* **Atomic**: a step directory is staged as ``step_N.tmp`` and renamed only
  after the manifest is fsync'd -- a preempted writer can never leave a
  half-checkpoint that restore would accept.
* **Mesh-elastic**: arrays are saved with their *global* logical shape
  (device_get assembles shards), so a checkpoint written on a 2-pod mesh
  restores onto 1 pod, 4 pods, or a laptop; resharding happens on load via
  ``jax.device_put`` with the target sharding.
* **Async**: the step loop snapshots to host memory and hands the write to a
  background thread; training never blocks on the filesystem.
* **Auto-resume**: ``latest_step``/``restore`` pick up the newest complete
  checkpoint, so a restarted job continues exactly where the last atomic
  rename left it.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3,
                 async_write: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    # -- write --------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        """Snapshot now; write in the background unless blocking."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        if self.async_write and not blocking:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_tree)

    def _write(self, step: int, host_tree: Any) -> None:
        final = self.dir / f"step_{step:09d}"
        tmp = self.dir / f"step_{step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_tree)
        manifest = {}
        for key, arr in flat.items():
            fname = key.replace("/", "__") + ".npy"
            np.save(tmp / fname, arr)
            manifest[key] = {"file": fname, "shape": list(arr.shape),
                             "dtype": str(arr.dtype)}
        with open(tmp / "manifest.json", "w") as f:
            json.dump({"step": step, "arrays": manifest}, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)          # atomicity boundary
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int]:
        """Restore into the structure of ``like``; reshard onto ``shardings``
        (any mesh) if given.  Returns (tree, step)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.load(open(d / "manifest.json"))["arrays"]
        flat_like = _flatten(like)
        loaded = {}
        for key in flat_like:
            if key not in manifest:
                raise KeyError(f"checkpoint missing array {key}")
            loaded[key] = np.load(d / manifest[key]["file"])
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        keys = list(_flatten(like).keys())
        tree = jax.tree_util.tree_unflatten(
            treedef, [loaded[k] for k in keys])
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, step
