"""repro.data"""
