"""Device-resident growable packed corpus for the match engine
(DESIGN.md Sec. 3a/3f).

The paper's core discipline is that the reference never moves once laid out
(CRAM-PM keeps fragments resident in the array rows; Sec. 2-3).  The TPU
analogue: pack the fragment matrix into its kernel-native forms *once*, keep
both forms device-resident, and serve every subsequent query from the cached
arrays.  Two forms exist because the two kernels want different layouts:

* SWAR form  -- (C_pad, W) uint32, 16 two-bit chars per word, rows padded to
  ``match_swar.ROW_TILE``; consumed by the VPU bit-parallel kernel.
* one-hot form -- (C_pad, F4) bf16, char-major flattened one-hot; consumed
  by the MXU correlation kernel.

Both are built lazily on first use and grown *on device* (zero-extension via
``jnp`` concat/pad) when a query needs more padding than a previous one --
host repacking happens at most once per form for a given corpus lifetime.
``host_pack_count`` counts those host->device packing events; the
steady-state invariant (no repacking across repeated queries *or corpus
growth*) is asserted by ``tests/test_match_engine.py``,
``tests/test_match_ingest.py`` and the engine/ingest benchmarks.

The corpus is **growable in place** (Sec. 3f): ``capacity`` row slots are
reserved up front (and doubled on demand), ``n_rows`` counts the *live*
rows, and ``append_rows`` packs only the appended rows on the host and
splices them into the cached device forms with ``.at[].set`` -- the
resident rows are never repacked, mirroring a CRAM row write into an
already-laid-out array.  Capacity growth itself is a device-side
zero-extension (``jnp.concatenate`` with zero rows), not a host repack.
``generation`` bumps on every content mutation (``append_rows`` /
``set_rows`` / ``tombstone`` / ``compact`` / ``invalidate``) so result
caches (match.service) never serve scores computed against older corpus
contents.

**Windowed operation** (DESIGN.md Sec. 3j): ``tombstone(rows)`` marks live
rows dead without moving anything -- the device forms are untouched and
the engine's reductions mask dead rows out on the host (threshold hits
drop, top-k excludes, best/full report the -1 sentinel).  ``compact()``
reclaims the dead slots by shifting the live tail down *in the host
buffer* and splicing only the moved rows into the device forms
(``_splice_device``), so eviction never repacks resident rows either --
the pack counters stay flat through an arbitrary tombstone/compact
history, which is what lets the corpus run as a bounded sliding window
instead of append-only.

**Row sharding** (``shard_rows``, DESIGN.md Sec. 3h): on a mesh the device
forms are stored in the *cyclic physical layout* of
``repro.distributed.sharding`` -- logical row ``r`` lives on shard
``r % S`` at slot ``r // S`` -- and placed with a ``NamedSharding`` over
the mesh row axes.  Block-sharding the permuted array is a cyclic
sharding of logical rows, which buys three properties at once: appends
round-robin across shards (ingest balanced by construction,
fewest-live-rows-first), capacity growth is a per-shard zero-extension
(a row's shard and slot never change), and contiguous logical chunks are
per-shard slot slices (no cross-device traffic while streaming).  The
host buffer and all public row ids stay logical; only the device forms
are permuted.

**Multi-host** (DESIGN.md Sec. 3k): under ``jax.distributed`` some mesh
shards live on other processes' devices, which eager ``device_put`` /
``.at[].set`` / ``reshape`` cannot touch.  The first pack then goes
through ``jax.make_array_from_callback`` -- *each process packs only the
shard blocks it owns* (block ``s`` of the cyclic layout is exactly
``pack(frags[s::S])``, so per-host packing is bit-identical to permuting
a global pack), keeping pack counters flat per host -- and every
subsequent splice or zero-extension runs as a jitted update (replicated
host operands in, XLA writes only addressable slots).  The host
fragment buffer stays fully replicated on every process by SPMD
discipline: ingest calls present identical rows on all processes.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import encoding
from repro.distributed import sharding as _sharding
from repro.kernels import match_swar as _swar
from repro.obs import NULL_OBS

from . import merge as _merge

ROW_TILE = _swar.ROW_TILE


def _one_hot_flat(fragments: np.ndarray) -> np.ndarray:
    """(R, F) uint8 codes -> (R, F*4) float32 char-major one-hot."""
    R, F = fragments.shape
    f1h = np.zeros((R, F, 4), np.float32)
    f1h[np.arange(R)[:, None], np.arange(F)[None, :], fragments] = 1.0
    return f1h.reshape(R, F * 4)


class PackedCorpus:
    """Fragments packed once into device-resident, growable kernel forms.

    ``fragments`` is the (R, F) uint8 code matrix of *live* rows (host copy
    kept as the source of truth for incremental updates and for the ``ref``
    backend); ``capacity`` row slots are reserved so appends are in-place
    row writes.  ``row_pad`` rounds the device row count up; the engine
    raises it above ROW_TILE when sharding over a mesh rows axis.
    """

    def __init__(self, fragments: np.ndarray, *, row_pad: int = ROW_TILE,
                 capacity: Optional[int] = None):
        # Own copy: set_rows/append_rows mutate, and the caller's array
        # must not change underneath the packed device forms.
        fragments = np.array(fragments, np.uint8)
        if fragments.ndim != 2:
            raise ValueError("fragments must be (R, F)")
        if row_pad % ROW_TILE:
            raise ValueError(f"row_pad must be a multiple of {ROW_TILE}")
        self.row_pad = row_pad
        self._n_rows = fragments.shape[0]
        cap = max(self._n_rows, 0 if capacity is None else int(capacity))
        if cap > self._n_rows:
            buf = np.zeros((cap, fragments.shape[1]), np.uint8)
            buf[:self._n_rows] = fragments
            fragments = buf
        self._frags = fragments               # (capacity, F) host buffer
        # Row-shard layout: device forms are cyclically permuted over
        # n_shards and placed with NamedSharding(mesh, row_axes) when a
        # mesh engine configures the corpus via shard_rows().
        self.n_shards = 1
        self._mesh = None
        self._row_axes = None
        # Cached device forms (lazy), sized to the padded capacity.
        self._swar: Optional[jnp.ndarray] = None      # (C_pad, W) uint32
        self._onehot: Optional[jnp.ndarray] = None    # (C_pad, F4) bf16
        # Observability handle (spans around pack/splice/compact, churn
        # counters).  The shared null default records metrics nobody
        # reads; an owning MatchEngine replaces it with its own.
        self.obs = NULL_OBS
        # Host->device full-corpus packing events, per form.
        self.swar_pack_count = 0
        self.onehot_pack_count = 0
        # Incremental row writes (device splice, not a repack).
        self.row_update_count = 0
        # Content generation: bumped on every mutation (append_rows /
        # set_rows / tombstone / compact / invalidate).  Result caches
        # keyed on it (match.service) drop entries computed against older
        # contents.
        self.generation = 0
        # Tombstone mask over the capacity buffer (windowed operation,
        # DESIGN.md Sec. 3j): a dead row stays physically resident (its
        # device-form words are untouched) but reductions mask it out;
        # compact() reclaims the slots.
        self._dead = np.zeros(self.capacity, bool)
        self.n_dead = 0
        self.n_compactions = 0
        # Attached derived forms (match.index.CorpusIndex): observers that
        # mirror the residency protocol -- notified of exactly the touched
        # rows on splices, of capacity growth, and of invalidation, so
        # they stay incrementally up to date without ever re-reading the
        # resident rows.
        self._indexes: list = []

    # -- geometry ------------------------------------------------------------
    @property
    def fragments(self) -> np.ndarray:
        """(n_rows, F) live rows -- a view into the capacity buffer."""
        return self._frags[:self._n_rows]

    @property
    def n_rows(self) -> int:
        """Live (appended) rows; grows under ``append_rows``."""
        return self._n_rows

    @property
    def capacity(self) -> int:
        """Reserved row slots; appends within capacity never reallocate."""
        return self._frags.shape[0]

    @property
    def fragment_chars(self) -> int:
        return self._frags.shape[1]

    @property
    def n_rows_padded(self) -> int:
        """Live rows rounded up to ``row_pad`` (what queries stream over)."""
        return -(-self._n_rows // self.row_pad) * self.row_pad

    @property
    def capacity_padded(self) -> int:
        """Capacity rounded up to ``row_pad`` (device-form row count)."""
        return -(-self.capacity // self.row_pad) * self.row_pad

    @property
    def host_pack_count(self) -> int:
        """Total host-side full-corpus packing events (both forms)."""
        return self.swar_pack_count + self.onehot_pack_count

    # -- tombstones (windowed operation, DESIGN.md Sec. 3j) --------------------
    @property
    def n_live(self) -> int:
        """Rows that are appended and not tombstoned."""
        return self._n_rows - self.n_dead

    @property
    def dead_mask(self) -> np.ndarray:
        """(n_rows,) bool tombstone mask over the live region (read-only)."""
        m = self._dead[:self._n_rows]
        m.flags.writeable = False
        return m

    def live_row_ids(self) -> np.ndarray:
        """Ascending logical ids of non-tombstoned rows."""
        return np.flatnonzero(~self._dead[:self._n_rows])

    # -- row sharding ----------------------------------------------------------
    @property
    def shard_stride(self) -> int:
        """Per-shard physical row stride J: physical(r) = (r%S)*J + r//S."""
        return self.capacity_padded // self.n_shards

    @property
    def shard_live_rows(self) -> np.ndarray:
        """(S,) live logical rows per shard under the cyclic layout.

        Shard ``s`` holds rows ``{r < n_rows : r % S == s}``; contiguous
        appends round-robin, so counts differ by at most one row -- the
        balanced-ingest invariant the service benchmark asserts.
        """
        S, n = self.n_shards, self._n_rows
        return np.array([max(0, (n - s + S - 1) // S) for s in range(S)],
                        np.int64)

    def shard_rows(self, mesh, row_axes, n_shards: int) -> None:
        """Configure the cyclic row layout + NamedSharding placement.

        Called by the engine after resolving the mesh row axes.  Raises
        ``row_pad`` to a multiple of ``ROW_TILE * n_shards`` (so padded
        row counts divide evenly over shards) and drops cached device
        forms when the layout actually changes -- forms built for a
        different shard count are permuted differently and cannot be
        reused.  Reconfiguring to the same layout is a no-op (no repack,
        no generation bump).
        """
        n_shards = max(1, int(n_shards))
        need_pad = ROW_TILE * n_shards
        relayout = (n_shards != self.n_shards
                    or self.row_pad % need_pad != 0
                    or (n_shards > 1 and self._mesh is not None
                        and mesh != self._mesh))
        self._mesh = mesh
        self._row_axes = row_axes
        self.n_shards = n_shards
        if not relayout:
            return
        if self.row_pad % need_pad:
            self.row_pad = need_pad
        if (self._swar is not None or self._onehot is not None
                or self._indexes):
            self.invalidate()

    @property
    def _multiprocess(self) -> bool:
        """Sharded over devices some of which another process owns."""
        return (self.n_shards > 1 and self._mesh is not None
                and jax.process_count() > 1)

    def _row_sharding(self) -> NamedSharding:
        return NamedSharding(self._mesh, PartitionSpec(self._row_axes))

    def _place(self, arr) -> jnp.ndarray:
        """Device placement: NamedSharding over the row axes when sharded.

        Multi-controller, ``arr`` is a replicated *host* array (identical
        on every process); each process materializes only the shard
        blocks its own devices hold.
        """
        if self.n_shards > 1 and self._mesh is not None:
            ns = self._row_sharding()
            if jax.process_count() > 1:
                a = np.asarray(arr)
                return jax.make_array_from_callback(
                    a.shape, ns, lambda idx: a[idx])
            return jax.device_put(arr, ns)
        return jnp.asarray(arr)

    def _grow_form_rows(self, form: jnp.ndarray, c_pad: int) -> jnp.ndarray:
        """Zero-extend a device form to ``c_pad`` rows, per shard.

        Single-shard: plain concat.  Sharded: the extension happens
        *inside* each shard's block -- reshape (S, J_old, w), pad slot
        axis, reshape back -- so every resident row keeps its shard and
        slot (growth stays in place per shard) and the result re-places
        onto the same NamedSharding.  Multi-controller the same program
        runs jitted (growth events are O(log capacity) per lifetime, so
        a fresh trace per doubling is fine): eager reshape of a
        non-addressable array would throw.
        """
        S, w = self.n_shards, form.shape[1]
        if S == 1:
            grown = jnp.concatenate(
                [form, jnp.zeros((c_pad - form.shape[0], w), form.dtype)], 0)
            return self._place(grown)
        j_old, j_new = form.shape[0] // S, c_pad // S

        def grow(f):
            f3 = f.reshape(S, j_old, w)
            f3 = jnp.concatenate(
                [f3, jnp.zeros((S, j_new - j_old, w), f.dtype)], 1)
            return f3.reshape(S * j_new, w)

        if self._multiprocess:
            return jax.jit(grow, out_shardings=self._row_sharding())(form)
        return self._place(grow(form))

    def _grow_form_cols(self, form: jnp.ndarray, grow: int) -> jnp.ndarray:
        """Zero-extend a device form's word/column axis, in place per row."""
        if self._multiprocess:
            return jax.jit(lambda f: jnp.pad(f, ((0, 0), (0, grow))),
                           out_shardings=self._row_sharding())(form)
        return self._place(jnp.pad(form, ((0, 0), (0, grow))))

    def attach_index(self, index) -> None:
        """Register a derived-form observer (see ``match.index``).

        The observer must expose ``_on_rows_written(start, rows)``,
        ``_on_capacity()`` and ``_on_invalidate()``; it is driven by the
        same mutation events that keep the SWAR/one-hot forms current.
        """
        self._indexes.append(index)

    def detach_index(self, index) -> None:
        """Stop notifying (and so stop updating) an attached observer.

        An abandoned index otherwise keeps re-deriving signatures on
        every row splice and pins its device form for the corpus
        lifetime; detach before replacing one configuration with
        another.  Detaching an index that is not attached is a no-op.
        """
        self._indexes = [ix for ix in self._indexes if ix is not index]

    @classmethod
    def from_reference(cls, ref_codes: np.ndarray, fragment_len: int,
                       pattern_len: int, *, row_pad: int = ROW_TILE
                       ) -> "PackedCorpus":
        """Fold a long reference into overlapping rows (Fig. 3 layout)."""
        frags = encoding.fold_reference(ref_codes, fragment_len, pattern_len)
        return cls(frags, row_pad=row_pad)

    # -- SWAR form -----------------------------------------------------------
    def swar_words(self, need_words: int) -> jnp.ndarray:
        """(C_pad, W >= need_words) uint32, device-resident.

        First call packs on the host (one event); later calls reuse the
        cached array, zero-extending on device if a query needs deeper
        word reads than any previous one.  Reserved (not yet live) rows
        pack to zero words -- code 0 packs to 0 -- so the form covers the
        whole capacity and appends are pure row splices.
        """
        if self._swar is None:
            tr = self.obs.tracer
            with tr.span("pack",
                         {"form": "swar", "rows": self.capacity_padded}
                         if tr.enabled else None):
                if self._multiprocess:
                    self._swar = self._build_swar_per_host(need_words)
                else:
                    words = encoding.pack_codes_u32(self._frags)
                    c_pad = self.capacity_padded
                    if c_pad > words.shape[0]:
                        words = np.concatenate(
                            [words,
                             np.zeros((c_pad - words.shape[0],
                                       words.shape[1]), np.uint32)], 0)
                    if words.shape[1] < need_words:
                        words = np.concatenate(
                            [words,
                             np.zeros((c_pad, need_words - words.shape[1]),
                                      np.uint32)], 1)
                    words = _sharding.cyclic_permute(words, self.n_shards)
                    self._swar = self._place(words)
            self.swar_pack_count += 1
            self.obs.metrics.counter("corpus.packs").inc()
        elif self._swar.shape[1] < need_words:
            self._swar = self._grow_form_cols(
                self._swar, need_words - self._swar.shape[1])
        return self._swar

    def _build_swar_per_host(self, need_words: int) -> jnp.ndarray:
        """First SWAR pack, multi-controller: each process packs only the
        shard blocks its devices own.

        Block ``s`` of ``cyclic_permute(pack(frags))`` is exactly
        ``pack(frags[s::S])`` (packing is row-wise), so per-host packing
        reproduces the single-process layout bit for bit while every
        host does ~1/P of the packing work.  Reserved rows are zero
        codes and pack to zero words, matching the zero row padding.
        """
        S, c_pad = self.n_shards, self.capacity_padded
        J = c_pad // S
        W = max(encoding.pack_codes_u32(self._frags[:1]).shape[1],
                need_words)
        blocks: dict = {}

        def cb(index):
            s = (index[0].start or 0) // J
            blk = blocks.get(s)
            if blk is None:
                words = encoding.pack_codes_u32(self._frags[s::S])
                blk = np.zeros((J, W), np.uint32)
                blk[:words.shape[0], :words.shape[1]] = words
                blocks[s] = blk
            return blk
        return jax.make_array_from_callback(
            (c_pad, W), self._row_sharding(), cb)

    # -- one-hot form ----------------------------------------------------------
    def onehot_flat(self, f_chars: int) -> jnp.ndarray:
        """(C_pad, F4 >= f_chars*4) bf16 one-hot, device-resident.

        Padding chars and reserved rows are all-zero one-hot (contribute 0
        to every score), so growing either way is a device-side
        zero-extension.  Rows are padded like the SWAR form so sharded
        chunks divide evenly over the mesh.
        """
        if self._onehot is None:
            tr = self.obs.tracer
            with tr.span("pack",
                         {"form": "onehot", "rows": self.capacity_padded}
                         if tr.enabled else None):
                if self._multiprocess:
                    self._onehot = self._build_onehot_per_host(f_chars)
                else:
                    base = _one_hot_flat(self._frags)
                    base[self._n_rows:] = 0.0   # reserved rows: all-zero
                    c_pad = self.capacity_padded
                    if c_pad > base.shape[0]:
                        base = np.concatenate(
                            [base,
                             np.zeros((c_pad - base.shape[0], base.shape[1]),
                                      np.float32)], 0)
                    need = max(f_chars, self.fragment_chars) * 4
                    if base.shape[1] < need:
                        base = np.concatenate(
                            [base, np.zeros((base.shape[0],
                                             need - base.shape[1]),
                                            np.float32)], 1)
                    base = _sharding.cyclic_permute(base, self.n_shards)
                    self._onehot = self._place(
                        jnp.asarray(base, jnp.bfloat16))
            self.onehot_pack_count += 1
            self.obs.metrics.counter("corpus.packs").inc()
        elif self._onehot.shape[1] < f_chars * 4:
            self._onehot = self._grow_form_cols(
                self._onehot, f_chars * 4 - self._onehot.shape[1])
        return self._onehot

    def _build_onehot_per_host(self, f_chars: int) -> jnp.ndarray:
        """First one-hot pack, multi-controller: per-host shard blocks.

        Shard ``s`` holds logical rows ``s::S``; its first
        ``ceil((n_rows - s) / S)`` slots are live and the rest must be
        all-zero one-hot (code-0 reserved rows would otherwise read as
        'A' columns), exactly as the single-process build zeroes
        ``base[n_rows:]`` before permuting.
        """
        S, c_pad = self.n_shards, self.capacity_padded
        J = c_pad // S
        need = max(f_chars, self.fragment_chars) * 4
        n = self._n_rows
        blocks: dict = {}

        def cb(index):
            s = (index[0].start or 0) // J
            blk = blocks.get(s)
            if blk is None:
                oh = _one_hot_flat(self._frags[s::S])
                live_s = max(0, (n - s + S - 1) // S)
                oh[live_s:] = 0.0
                blk = np.zeros((J, need), np.float32)
                blk[:oh.shape[0], :oh.shape[1]] = oh
                blocks[s] = blk = np.asarray(blk, dtype=jnp.bfloat16)
            return blk
        return jax.make_array_from_callback(
            (c_pad, need), self._row_sharding(), cb)

    # -- growth ----------------------------------------------------------------
    def reserve(self, capacity: int) -> None:
        """Grow reserved row slots to at least ``capacity``, in place.

        The host buffer extends with zero rows (a memcpy of raw codes, not
        a packing event) and the cached device forms pad-extend with
        device-side ``jnp.concatenate`` -- the resident packed rows are
        never re-read or re-packed on the host, and the pack counters do
        not move.  Contents are unchanged, so ``generation`` holds too.
        """
        capacity = int(capacity)
        if capacity < self._n_rows:
            # A shrink below the live region would drop resident rows the
            # device forms still serve; refuse loudly instead of silently
            # ignoring the request.
            raise ValueError(
                f"cannot reserve capacity {capacity} below the live row "
                f"count: corpus holds {self._n_rows} live rows (capacity "
                f"{self.capacity}); shrinking a PackedCorpus is not "
                "supported")
        if capacity <= self.capacity:
            return
        grow = np.zeros((capacity - self.capacity, self.fragment_chars),
                        np.uint8)
        self._dead = np.concatenate(
            [self._dead, np.zeros(capacity - self.capacity, bool)])
        self._frags = np.concatenate([self._frags, grow], 0)
        c_pad = self.capacity_padded
        if self._swar is not None and self._swar.shape[0] < c_pad:
            self._swar = self._grow_form_rows(self._swar, c_pad)
        if self._onehot is not None and self._onehot.shape[0] < c_pad:
            self._onehot = self._grow_form_rows(self._onehot, c_pad)
        for ix in self._indexes:
            ix._on_capacity()

    def append_rows(self, rows: np.ndarray) -> int:
        """Append live rows in place; returns the first new row's index.

        Packs only the appended rows on the host and splices them into the
        cached device forms (``.at[].set``) -- zero host repacks of the
        resident rows, ever.  Capacity doubles on demand (amortized O(1)
        row writes per append); ``generation`` bumps once per call so
        generation-keyed caches see every append.
        """
        rows = np.asarray(rows, np.uint8)
        if rows.ndim == 1:
            rows = rows[None, :]
        if rows.ndim != 2 or rows.shape[1] != self.fragment_chars:
            raise ValueError(
                f"appended rows must be (n, {self.fragment_chars}); got "
                f"shape {rows.shape}")
        n = rows.shape[0]
        if n == 0:
            # An empty append is a no-op: no device launch, no generation
            # bump (a bump would needlessly drop every generation-keyed
            # result cache for contents that did not change).
            return self._n_rows
        start = self._n_rows
        if start + n > self.capacity:
            self.reserve(max(self.capacity * 2, start + n, ROW_TILE))
        self._frags[start:start + n] = rows
        self._n_rows = start + n
        self._splice_device(start, rows)
        self.generation += 1
        return start

    # -- incremental updates ---------------------------------------------------
    def _splice_device(self, start: int, rows: np.ndarray) -> None:
        """Pack ``rows`` (host, touched rows only) into the cached forms.

        Sharded forms scatter to the rows' *physical* (cyclic) positions;
        logical row ids never leak into the layout.
        """
        tr = self.obs.tracer
        with tr.span("pack",
                     {"form": "splice", "rows": rows.shape[0]}
                     if tr.enabled else None):
            self._splice_impl(start, rows)
        self.obs.metrics.counter("corpus.splice_rows").inc(rows.shape[0])

    def _splice_impl(self, start: int, rows: np.ndarray) -> None:
        n = rows.shape[0]
        phys = None
        mp = self._multiprocess
        if self.n_shards > 1:
            phys = _sharding.cyclic_physical_rows(
                np.arange(start, start + n), self.n_shards,
                self.shard_stride)
        if self._swar is not None:
            words = encoding.pack_codes_u32(rows)
            w = self._swar.shape[1]
            if words.shape[1] < w:
                words = np.concatenate(
                    [words, np.zeros((n, w - words.shape[1]), np.uint32)], 1)
            if phys is None:
                self._swar = self._swar.at[start:start + n, :].set(
                    jnp.asarray(words))
            elif mp:
                # Jitted scatter with replicated host operands: every
                # process computes the same update, XLA writes only the
                # slots its devices hold (eager .at[] would throw on
                # non-addressable shards).
                self._swar = _merge.scatter_rows(self._swar, phys, words)
            else:
                self._swar = self._swar.at[jnp.asarray(phys), :].set(
                    jnp.asarray(words))
        if self._onehot is not None:
            oh = _one_hot_flat(rows)
            w = self._onehot.shape[1]
            if oh.shape[1] < w:
                oh = np.concatenate(
                    [oh, np.zeros((n, w - oh.shape[1]), np.float32)], 1)
            if phys is None:
                self._onehot = self._onehot.at[start:start + n, :].set(
                    jnp.asarray(oh, jnp.bfloat16))
            elif mp:
                self._onehot = _merge.scatter_rows(
                    self._onehot, phys, np.asarray(oh, dtype=jnp.bfloat16))
            else:
                self._onehot = self._onehot.at[jnp.asarray(phys), :].set(
                    jnp.asarray(oh, jnp.bfloat16))
        for ix in self._indexes:
            ix._on_rows_written(start, rows)
        self.row_update_count += n

    def set_rows(self, start: int, rows: np.ndarray) -> None:
        """Overwrite live rows [start, start+n) -- packs only those rows.

        The cached device forms are updated in place (``.at[].set``), so a
        growing store (dedup) never repacks its resident rows.  Writes
        past the live region are rejected: grow with ``append_rows``.
        """
        rows = np.asarray(rows, np.uint8)
        if rows.ndim == 1:
            rows = rows[None, :]
        n = rows.shape[0]
        if rows.shape[1] != self.fragment_chars:
            raise ValueError(
                f"row width mismatch: rows have {rows.shape[1]} chars, "
                f"corpus fragments have {self.fragment_chars}")
        if start < 0 or start + n > self._n_rows:
            raise ValueError(
                f"row range [{start}, {start + n}) out of bounds for "
                f"{self._n_rows} live rows (capacity {self.capacity}); "
                "use append_rows to grow the corpus")
        self._frags[start:start + n] = rows
        self._splice_device(start, rows)
        self.generation += 1

    # -- eviction (windowed operation, DESIGN.md Sec. 3j) ----------------------
    def tombstone(self, rows) -> int:
        """Mark live rows dead; returns how many were newly tombstoned.

        O(1) device work: nothing moves and no form is touched -- the
        mask is host state that the engine's reductions honor (dead rows
        produce no threshold hits, are excluded from top-k, and report
        the -1 best-score sentinel).  ``generation`` bumps when the mask
        actually changed, so result caches never serve scores that
        include since-evicted rows.  Re-tombstoning a dead row is a
        no-op; reclaim the slots with ``compact()``.
        """
        rows = np.atleast_1d(np.asarray(rows, np.int64))
        if rows.size == 0:
            return 0
        if rows.min() < 0 or rows.max() >= self._n_rows:
            raise ValueError(
                f"tombstone rows must be in [0, {self._n_rows}), got "
                f"[{rows.min()}, {rows.max()}]")
        newly = int((~self._dead[rows]).sum())
        if newly:
            self._dead[rows] = True
            self.n_dead += newly
            self.generation += 1
            self.obs.metrics.counter("corpus.tombstoned_rows").inc(newly)
        return newly

    def compact(self) -> int:
        """Reclaim tombstoned slots; returns the number of rows dropped.

        Live rows shift down in the host buffer (order preserved: logical
        ids above a dead row shrink by the dead count below them) and only
        the rows at or after the first dead slot are re-spliced into the
        cached device forms -- the same touched-rows-only
        ``_splice_device`` path appends use, so the pack counters stay
        flat no matter how many eviction cycles the corpus lives through.
        The vacated tail is zeroed (and spliced as zeros) so it behaves
        exactly like reserved capacity.  No-op when nothing is dead.
        """
        if self.n_dead == 0:
            return 0
        tr = self.obs.tracer
        with tr.span("compact",
                     {"n_dead": self.n_dead} if tr.enabled else None):
            old_n = self._n_rows
            dead = self._dead[:old_n]
            first = int(np.argmax(dead))
            live_after = np.flatnonzero(~dead[first:]) + first
            new_n = first + live_after.size
            # Copy before overwrite: source and destination ranges
            # overlap.
            moved = np.array(self._frags[live_after])
            self._frags[first:new_n] = moved
            self._frags[new_n:old_n] = 0
            self._dead[:old_n] = False
            self.n_dead = 0
            self._n_rows = new_n
            # One splice covers the moved rows and the zeroed tail;
            # observers (CorpusIndex) ride the same notification.
            self._splice_device(first, self._frags[first:old_n])
            self.generation += 1
            self.n_compactions += 1
        self.obs.metrics.counter("corpus.compactions").inc()
        return old_n - new_n

    def invalidate(self) -> None:
        """Drop cached device forms (next query repacks)."""
        self._swar = None
        self._onehot = None
        for ix in self._indexes:
            ix._on_invalidate()
        self.generation += 1
