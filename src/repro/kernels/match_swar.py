"""SWAR bit-parallel sliding string match -- Pallas TPU kernel.

TPU adaptation of CRAM-PM Phase 1+2 (DESIGN.md Sec. 2b): 16 two-bit
characters per uint32 lane; one VPU op compares 8x128x16 characters -- the
analogue of a row-wide gang of XOR/NOR gates -- and the popcount reduction
tree becomes branch-free SWAR arithmetic.  The match string never leaves
VMEM (the CRAM analogy: the match string never leaves the row).

Data layout:
  ref_words  (R, W)  uint32 -- folded reference fragments, 16 chars/word,
                               padded with >= 1 zero word at the end.
  pat_words  (R, Wp) uint32 -- per-row pattern (broadcast for shared).
  valid_mask (1, Wp) uint32 -- low-bit-of-lane mask of valid pattern chars.
  out        (R, L)  int32  -- similarity scores per alignment.

Grid: one program per row tile; the alignment loop runs inside the kernel so
the reference tile is read from HBM exactly once per pattern block (the
paper's data-movement-minimization objective, expressed HBM->VMEM).

``match_swar_masks`` is the accept-set variant (the reconfigurable-logic
story of the paper, Sec. 1/3: same resident data, reprogrammed match
logic): instead of one packed pattern word per 16 positions it takes four
*bit-planes* -- plane c has the low bit of lane i set iff DNA code c is
accepted at pattern position i -- and a window lane scores a match iff its
character's plane accepts it.  IUPAC ambiguity codes, N wildcards and
arbitrary character classes all lower to these planes; exact matching is
the one-hot special case (but rides the cheaper XOR kernel above).

  pat_planes (R, 4*Wp) uint32 -- planes concatenated along words:
                                 plane c occupies columns [c*Wp, (c+1)*Wp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.tiling import coarse_row_tile

M1 = np.uint32(0x55555555)
M2 = np.uint32(0x33333333)
M4 = np.uint32(0x0F0F0F0F)
MUL = np.uint32(0x01010101)
# Code c replicated into every 2-bit lane (lane equality test operand).
CODE_LANES = tuple(np.uint32(c * 0x55555555) for c in range(4))

ROW_TILE = 8  # sublane-aligned row tile


def _swar_kernel(ref_ref, pat_ref, mask_ref, out_ref, *, n_locs: int,
                 pattern_chars: int, wp: int):
    pat = pat_ref[...]                       # (ROW_TILE, Wp)
    mask = mask_ref[...]                     # (1, Wp)

    def body(loc, _):
        base = loc // 16
        sh = (loc % 16).astype(jnp.uint32) * 2
        seg = ref_ref[:, pl.ds(base, wp + 1)]            # (ROW_TILE, Wp+1)
        lo = seg[:, :wp] >> sh
        hi_sh = (jnp.uint32(32) - sh) & jnp.uint32(31)
        hi = jnp.where(sh == 0, jnp.uint32(0), seg[:, 1:] << hi_sh)
        window = lo | hi
        diff = window ^ pat
        mism = (diff | (diff >> jnp.uint32(1))) & M1 & mask
        # <=1 bit per 2-bit lane: SWAR popcount starting at stage 2.
        v = (mism & M2) + ((mism >> jnp.uint32(2)) & M2)
        v = (v + (v >> jnp.uint32(4))) & M4
        mismatches = ((v * MUL) >> jnp.uint32(24)).astype(jnp.int32).sum(
            axis=-1, keepdims=True)
        out_ref[:, pl.ds(loc, 1)] = pattern_chars - mismatches
        return 0

    jax.lax.fori_loop(0, n_locs, body, 0)


@functools.partial(jax.jit, static_argnames=("n_locs", "pattern_chars",
                                             "interpret"))
def match_swar(ref_words: jnp.ndarray, pat_words: jnp.ndarray,
               valid_mask: jnp.ndarray, *, n_locs: int, pattern_chars: int,
               interpret: bool = False) -> jnp.ndarray:
    """Packed sliding match: see module docstring for layouts."""
    R, W = ref_words.shape
    Wp = pat_words.shape[1]
    if R % ROW_TILE:
        raise ValueError(f"rows must be padded to a multiple of {ROW_TILE}")
    # Row-elementwise body: coarsen the dispatch tile (kernels.tiling) so
    # launch overhead amortizes at scale; output is bit-identical.
    tile = coarse_row_tile(R, ROW_TILE, (W + Wp + n_locs) * 4)
    grid = (R // tile,)
    kernel = functools.partial(_swar_kernel, n_locs=n_locs,
                               pattern_chars=pattern_chars, wp=Wp)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, W), lambda i: (i, 0)),
            pl.BlockSpec((tile, Wp), lambda i: (i, 0)),
            pl.BlockSpec((1, Wp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n_locs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, n_locs), jnp.int32),
        interpret=interpret,
    )(ref_words, pat_words, valid_mask)


def _swar_masks_kernel(ref_ref, plane_ref, mask_ref, out_ref, *,
                       n_locs: int, pattern_chars: int, wp: int):
    planes = plane_ref[...]                  # (ROW_TILE, 4*Wp)
    valid = mask_ref[...]                    # (1, Wp)

    def body(loc, _):
        base = loc // 16
        sh = (loc % 16).astype(jnp.uint32) * 2
        seg = ref_ref[:, pl.ds(base, wp + 1)]            # (ROW_TILE, Wp+1)
        lo = seg[:, :wp] >> sh
        hi_sh = (jnp.uint32(32) - sh) & jnp.uint32(31)
        hi = jnp.where(sh == 0, jnp.uint32(0), seg[:, 1:] << hi_sh)
        window = lo | hi
        # Accept bit per lane: lane equals code c (both bits of the XOR
        # clear) AND plane c accepts position i.  Four equality tests
        # replace the single XOR of the exact kernel -- still branch-free
        # VPU work, no decode of the 2-bit characters.
        accept = jnp.zeros_like(window)
        for c in range(4):
            diff = window ^ CODE_LANES[c]
            eq = ~(diff | (diff >> jnp.uint32(1))) & M1
            accept |= eq & planes[:, c * wp:(c + 1) * wp]
        mism = valid & ~accept
        # <=1 bit per 2-bit lane: SWAR popcount starting at stage 2.
        v = (mism & M2) + ((mism >> jnp.uint32(2)) & M2)
        v = (v + (v >> jnp.uint32(4))) & M4
        mismatches = ((v * MUL) >> jnp.uint32(24)).astype(jnp.int32).sum(
            axis=-1, keepdims=True)
        out_ref[:, pl.ds(loc, 1)] = pattern_chars - mismatches
        return 0

    jax.lax.fori_loop(0, n_locs, body, 0)


@functools.partial(jax.jit, static_argnames=("n_locs", "pattern_chars",
                                             "interpret"))
def match_swar_masks(ref_words: jnp.ndarray, pat_planes: jnp.ndarray,
                     valid_mask: jnp.ndarray, *, n_locs: int,
                     pattern_chars: int,
                     interpret: bool = False) -> jnp.ndarray:
    """Accept-set sliding match: see module docstring for layouts."""
    R, W = ref_words.shape
    W4 = pat_planes.shape[1]
    if W4 % 4:
        raise ValueError("pat_planes must hold 4 concatenated plane blocks")
    wp = W4 // 4
    if R % ROW_TILE:
        raise ValueError(f"rows must be padded to a multiple of {ROW_TILE}")
    tile = coarse_row_tile(R, ROW_TILE, (W + W4 + n_locs) * 4)
    grid = (R // tile,)
    kernel = functools.partial(_swar_masks_kernel, n_locs=n_locs,
                               pattern_chars=pattern_chars, wp=wp)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, W), lambda i: (i, 0)),
            pl.BlockSpec((tile, W4), lambda i: (i, 0)),
            pl.BlockSpec((1, wp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n_locs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, n_locs), jnp.int32),
        interpret=interpret,
    )(ref_words, pat_planes, valid_mask)
