"""Near-duplicate filtering on the match engine (paper technique as a
first-class data-pipeline feature; DESIGN.md Sec. 4).

Documents are fingerprinted as 2-bit character streams (each byte ->
4 crumbs) and stored one-per-row exactly like the paper's folded reference
(Fig. 3).  The store is a ``repro.match.MatchEngine`` over a **growable**
``PackedCorpus`` (DESIGN.md Sec. 3f): adding a document is one in-place
``append_rows`` -- the CRAM row-write analogue -- which packs only the new
row and splices it into the device-resident forms.  Capacity doubles on
demand *inside the corpus* (a device-side zero-extension), so the engine,
its compile cache, and the resident packed rows all survive growth: the
store never repacks a resident row and never rebuilds its engine, the
keep-data-next-to-compute discipline doing production data-plane work
while ingesting.
"""

from __future__ import annotations

import warnings
from typing import List, Optional, Union

import numpy as np

from repro.match import MatchEngine, MatchQuery, PackedCorpus

_INITIAL_CAPACITY = 64

Doc = Union[bytes, np.ndarray]


def fingerprint(doc: bytes, length: int = 128) -> np.ndarray:
    """First `length` 2-bit crumbs of the document (byte -> 4 crumbs).

    Documents longer than ``length`` crumbs are truncated by design --
    the fingerprint is a fixed-width prefix signature.  Callers holding a
    precomputed fingerprint array should pass it straight to
    ``CRAMDedup.add`` / ``is_duplicate``, which reject (rather than
    silently truncate) arrays wider than the store's ``fp_len``.
    """
    raw = np.frombuffer(doc[: (length + 3) // 4], np.uint8)
    crumbs = np.stack([(raw >> (2 * i)) & 3 for i in range(4)], 1).reshape(-1)
    out = np.zeros(length, np.uint8)
    out[:min(len(crumbs), length)] = crumbs[:length]
    return out


class CRAMDedup:
    """Row-parallel near-dup store on one lifetime match engine.

    The store is the 'reference' (one fingerprint per row, all rows matched
    in lock step); the candidate is the 'pattern'.  A pattern shorter than
    the fragment slides, so prefix-shifted duplicates are caught too.
    ``backend=None`` lets the planner pick the kernel per query size.

    Documents may be raw ``bytes`` (fingerprinted here) or precomputed
    uint8 fingerprint arrays (values 0..3); an array wider than ``fp_len``
    is an error -- silently truncating it would quietly conflate distinct
    documents.
    """

    def __init__(self, fp_len: int = 128, pattern_len: int = 96,
                 threshold: float = 0.9, backend: Optional[str] = None,
                 method: Optional[str] = None):
        if method is not None:
            warnings.warn("CRAMDedup(method=...) is deprecated; pass "
                          "backend=...", DeprecationWarning, stacklevel=2)
        if pattern_len > fp_len:
            raise ValueError(f"pattern_len ({pattern_len}) cannot exceed "
                             f"fp_len ({fp_len})")
        self.fp_len = fp_len
        self.pattern_len = pattern_len
        self.threshold = threshold
        self.backend = backend if backend is not None else method
        # One corpus and one engine for the store's whole lifetime: growth
        # happens *inside* the corpus (append_rows + capacity doubling),
        # never by rebuilding the engine -- the resident packed rows and
        # the engine's compile cache survive every add.
        self._engine = MatchEngine(PackedCorpus(
            np.zeros((0, fp_len), np.uint8), capacity=_INITIAL_CAPACITY))

    def __len__(self) -> int:
        return self._engine.corpus.n_rows

    @property
    def engine(self) -> MatchEngine:
        return self._engine

    @property
    def capacity(self) -> int:
        return self._engine.corpus.capacity

    @property
    def total_host_packs(self) -> int:
        """Full host packing events over the store's lifetime (<= 1/form)."""
        return self._engine.corpus.host_pack_count

    @property
    def total_row_writes(self) -> int:
        """Incremental packed-row writes over the store's lifetime."""
        return self._engine.corpus.row_update_count

    def _fingerprint(self, doc: Doc) -> np.ndarray:
        if isinstance(doc, np.ndarray):
            fp = np.asarray(doc, np.uint8).reshape(-1)
            if fp.size > self.fp_len:
                raise ValueError(
                    f"fingerprint has {fp.size} crumbs but this store was "
                    f"built with fp_len={self.fp_len}; truncating would "
                    "conflate distinct documents -- pass at most fp_len "
                    "crumbs or rebuild the store with a larger fp_len")
            out = np.zeros(self.fp_len, np.uint8)
            out[:fp.size] = fp
            return out
        return fingerprint(doc, self.fp_len)

    def _similarity(self, doc: Doc) -> float:
        if len(self) == 0:
            return 0.0
        pat = self._fingerprint(doc)[: self.pattern_len]
        query = MatchQuery.exact(pat, reduction="best",
                                 backend=self.backend)
        # The engine scans live rows only; a compiled query is reused
        # across adds (geometry revalidates as the store grows).
        res = self._engine.match(query)
        return float(res.best_scores.max()) / self.pattern_len

    def is_duplicate(self, doc: Doc) -> bool:
        return self._similarity(doc) >= self.threshold

    def add(self, doc: Doc) -> None:
        """Append one document's fingerprint: an in-place packed row write."""
        self._engine.corpus.append_rows(self._fingerprint(doc))

    def filter(self, docs: List[Doc]) -> List[Doc]:
        """Greedy near-dup filter: keep a doc iff not similar to any kept."""
        kept = []
        for d in docs:
            if not self.is_duplicate(d):
                kept.append(d)
                self.add(d)
        return kept
