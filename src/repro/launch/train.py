"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the end-to-end loop (data -> train_step -> checkpoint) on whatever
devices exist: on this CPU container use ``--smoke`` (reduced config) or a
custom width; on a real slice the same entry point shards over the
production mesh (the dry-run proves the shardings compile).
"""

from __future__ import annotations

import argparse
import dataclasses

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS, get_config
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.runtime import loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    opt_cfg = adamw.OptConfig(peak_lr=args.lr, warmup_steps=20,
                              decay_steps=max(args.steps, 100))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    res = loop.train(cfg, opt_cfg, data, args.steps, ckpt=ckpt,
                     ckpt_every=args.ckpt_every)
    print(f"done: {res.final_step} steps, "
          f"loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}, "
          f"median step {sorted(res.step_times)[len(res.step_times)//2]*1e3:.1f} ms, "
          f"stragglers {len(res.straggler_events)}")


if __name__ == "__main__":
    main()
