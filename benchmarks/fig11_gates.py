"""Paper Fig. 11: bulk bitwise throughput (GOps) vs Ambit / Pinatubo.
Anchored ratios (see costmodel): NOT 178x, XOR 1.34x, Pinatubo-OR ~6x
(near-term); long-term scaling comes out of the device model (~2.15x vs
paper's 370/178=2.08x)."""

import time

from repro.core import costmodel as cm
from repro.core.tech import LONG_TERM, NEAR_TERM

PAPER_RATIOS = {"NOT": (178, 370), "XOR": (1.34, 4.0)}


def run():
    rows = []
    for op in ("NOT", "OR", "NAND", "XOR"):
        t0 = time.perf_counter()
        near = cm.bulk_gops(op, NEAR_TERM)
        longt = cm.bulk_gops(op, LONG_TERM)
        us = (time.perf_counter() - t0) * 1e6
        ambit = cm.AMBIT_GOPS[op]
        extra = ""
        if op in PAPER_RATIOS:
            extra = f" paper={PAPER_RATIOS[op][0]}x/{PAPER_RATIOS[op][1]}x"
        rows.append((f"fig11/{op}", round(us, 1),
                     f"near={near:.4g}GOps long={longt:.4g}GOps"
                     f" vs_ambit={near/ambit:.3g}x/{longt/ambit:.3g}x" + extra))
    near_or = cm.bulk_gops("OR", NEAR_TERM)
    long_or = cm.bulk_gops("OR", LONG_TERM)
    rows.append(("fig11/vs_pinatubo_OR", 0.0,
                 f"near={near_or/cm.PINATUBO_OR_GOPS:.3g}x"
                 f" long={long_or/cm.PINATUBO_OR_GOPS:.3g}x paper=~6x/12x"))
    return rows
