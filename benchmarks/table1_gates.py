"""Paper Table 1 / Table 3: gate truth tables emerge from the analog model;
derived V_gate windows vs the paper's reported ranges."""

import itertools

from repro.core import gates
from repro.core.tech import NEAR_TERM, LONG_TERM, PAPER_VGATE_V


def run():
    rows = []
    for tech in (NEAR_TERM, LONG_TERM):
        paper = PAPER_VGATE_V[tech.name]
        for g in ("INV", "COPY", "NOR", "MAJ3", "MAJ5", "TH"):
            lo, hi = gates.vgate_window(g, tech)
            spec = gates.GATES[g]
            ok = all(gates.analog_gate_output(g, b, tech) == spec.truth(b)
                     for b in itertools.product((0, 1), repeat=spec.arity))
            p = paper.get(g)
            rows.append((
                f"table1/{tech.name}/{g}", 0.0,
                f"window=({lo:.3f},{hi:.3f})V paper={p} truth_ok={ok}"))
        study = gates.variation_study(tech)
        rows.append((f"table1/{tech.name}/variation", 0.0,
                     f"pm_gates_distinct={study['pm_gates_structurally_distinct']}"))
    return rows
