"""mamba2-130m [ssm]: SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  24L d_model=768 d_ff=0 vocab=50280,
ssm_state=128, expand=2, head_dim=64 (24 SSD heads).  tp_pad=1: the inner
width (1536) shards 16-way on the model axis; tiny per-head vectors
replicate.  Sub-quadratic -> runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv_heads=24, head_dim=32,
    d_ff=0, vocab=50_280,
    block_pattern=("ssd",),
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    tie_embeddings=True,
    tp_pad=1,
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke", family="ssm",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab=256,
    block_pattern=("ssd",),
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
    tie_embeddings=True,
    tp_pad=1, vocab_pad=1, remat=False,
)
