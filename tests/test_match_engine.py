"""Match-engine subsystem tests: planner decisions, corpus residency,
streaming reductions, sharded execution, oracle equivalence.

The engine must be bit-identical to ``matcher.sliding_scores`` on every
tested shape (acceptance criterion), and the packed corpus must never be
host-repacked after the first query (the paper's data-residency
discipline, asserted via the corpus pack counters).
"""

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core.matcher import sliding_scores
from repro.match import MatchEngine, PackedCorpus, Planner


def case(r, f, p, *, per_row=False, q=None, seed=0):
    rng = np.random.default_rng(seed)
    frags = rng.integers(0, 4, (r, f), np.uint8)
    if q is not None:
        pats = rng.integers(0, 4, (q, p), np.uint8)
    elif per_row:
        pats = rng.integers(0, 4, (r, p), np.uint8)
    else:
        pats = rng.integers(0, 4, p, np.uint8)
    return frags, pats


class TestPlanner:
    def setup_method(self):
        self.planner = Planner()

    def plan(self, **kw):
        return self.planner.plan(**kw)

    def test_per_row_forces_swar(self):
        p = self.plan(n_rows=64, fragment_chars=512, pattern_chars=100,
                      per_row=True)
        assert p.backend == "swar" and p.mode == "per_row"

    def test_large_batch_picks_mxu(self):
        p = self.plan(n_rows=512, fragment_chars=1024, pattern_chars=100,
                      n_patterns=128)
        assert p.backend == "mxu" and p.mode == "batched"

    def test_shared_picks_swar(self):
        p = self.plan(n_rows=512, fragment_chars=1024, pattern_chars=100)
        assert p.backend == "swar" and p.mode == "shared"

    def test_tiny_picks_ref(self):
        p = self.plan(n_rows=2, fragment_chars=20, pattern_chars=8)
        assert p.backend == "ref"

    def test_explicit_override_wins(self):
        p = self.plan(n_rows=2, fragment_chars=20, pattern_chars=8,
                      backend="mxu")
        assert p.backend == "mxu"
        assert p.reason == "explicit override [cost=static]"
        assert p.cost_source == "static"

    def test_mxu_per_row_rejected(self):
        with pytest.raises(ValueError, match="per-row"):
            self.plan(n_rows=8, fragment_chars=64, pattern_chars=16,
                      per_row=True, backend="mxu")

    def test_pattern_longer_than_fragment_rejected(self):
        with pytest.raises(ValueError, match="longer"):
            self.plan(n_rows=8, fragment_chars=16, pattern_chars=17)

    def test_geometry_carried_on_plan(self):
        p = self.plan(n_rows=20, fragment_chars=300, pattern_chars=100,
                      backend="swar")
        assert p.n_locs == 201
        assert p.wp == 7                      # ceil(100/16)
        assert p.need_words == (200 // 16) + 7 + 1
        assert p.chunk_rows % 8 == 0 and p.chunk_rows <= 24

    def test_chunk_rows_override_rounds_to_tile(self):
        p = self.plan(n_rows=100, fragment_chars=300, pattern_chars=100,
                      backend="swar", chunk_rows=20)
        assert p.chunk_rows == 24             # rounded up to ROW_TILE


class TestOracleEquivalence:
    """Engine results must be bit-identical to matcher.sliding_scores."""

    @pytest.mark.parametrize("r,f,p", [
        (1, 20, 5), (3, 33, 16), (13, 70, 20),   # R not multiple of ROW_TILE
        (8, 64, 64),                             # P == F (single alignment)
        (5, 128, 1), (10, 300, 100), (7, 257, 31),
    ])
    @pytest.mark.parametrize("backend", ["swar", "mxu", "ref", None])
    def test_shared(self, r, f, p, backend):
        frags, pat = case(r, f, p, seed=r * f + p)
        got = np.asarray(MatchEngine(frags).scores(pat, backend=backend))
        np.testing.assert_array_equal(got, sliding_scores(frags, pat))

    @pytest.mark.parametrize("r,f,p", [(4, 50, 10), (9, 120, 48),
                                       (6, 40, 40)])
    def test_per_row(self, r, f, p):
        frags, pats = case(r, f, p, per_row=True, seed=7)
        got = np.asarray(MatchEngine(frags).scores(pats))
        np.testing.assert_array_equal(got, sliding_scores(frags, pats))

    @pytest.mark.parametrize("r,f,p,q", [(2, 40, 8, 3), (5, 300, 100, 4),
                                         (3, 64, 64, 2)])
    @pytest.mark.parametrize("backend", ["swar", "mxu", "ref"])
    def test_batched(self, r, f, p, q, backend):
        frags, pats = case(r, f, p, q=q, seed=r + f + p + q)
        got = np.asarray(MatchEngine(frags).scores(pats, backend=backend))
        want = np.stack([sliding_scores(frags, pats[i]) for i in range(q)], -1)
        np.testing.assert_array_equal(got, want)

    def test_randomized_shapes(self):
        rng = np.random.default_rng(123)
        for _ in range(6):
            f = int(rng.integers(4, 120))
            p = int(rng.integers(1, f + 1))     # includes P == F
            r = int(rng.integers(1, 20))        # includes R % ROW_TILE != 0
            frags, pat = case(r, f, p, seed=int(rng.integers(2**31)))
            got = np.asarray(MatchEngine(frags).scores(pat))
            np.testing.assert_array_equal(got, sliding_scores(frags, pat))


class TestCorpusResidency:
    def test_packed_once_across_queries(self):
        rng = np.random.default_rng(0)
        frags = rng.integers(0, 4, (24, 200), np.uint8)
        eng = MatchEngine(frags)
        for seed in range(4):
            pat = np.random.default_rng(seed).integers(0, 4, 50, np.uint8)
            eng.scores(pat, backend="swar")
        assert eng.corpus.swar_pack_count == 1
        assert eng.corpus.host_pack_count == 1

    def test_deeper_query_grows_on_device(self):
        """A later query needing more padding zero-extends the cached device
        array instead of repacking on the host."""
        rng = np.random.default_rng(1)
        frags = rng.integers(0, 4, (8, 200), np.uint8)
        eng = MatchEngine(frags)
        big = rng.integers(0, 4, 16, np.uint8)      # need = 13 words
        small = rng.integers(0, 4, 5, np.uint8)     # need = 14 words
        np.testing.assert_array_equal(
            eng.scores(big, backend="swar"), sliding_scores(frags, big))
        w0 = eng.corpus._swar.shape[1]
        np.testing.assert_array_equal(
            eng.scores(small, backend="swar"), sliding_scores(frags, small))
        assert eng.corpus._swar.shape[1] > w0
        assert eng.corpus.swar_pack_count == 1      # still one host pack

    def test_both_forms_cached_independently(self):
        rng = np.random.default_rng(2)
        frags = rng.integers(0, 4, (8, 100), np.uint8)
        pats = rng.integers(0, 4, (4, 30), np.uint8)
        eng = MatchEngine(frags)
        eng.scores(pats[0], backend="swar")
        eng.scores(pats, backend="mxu")
        eng.scores(pats, backend="mxu")
        assert eng.corpus.swar_pack_count == 1
        assert eng.corpus.onehot_pack_count == 1

    def test_set_rows_updates_device_forms(self):
        rng = np.random.default_rng(3)
        frags = rng.integers(0, 4, (10, 60), np.uint8)
        pat = rng.integers(0, 4, 12, np.uint8)
        eng = MatchEngine(frags)
        eng.scores(pat, backend="swar")             # pack
        eng.scores(np.stack([pat]), backend="mxu")  # pack one-hot too
        new_row = rng.integers(0, 4, 60, np.uint8)
        new_row[20:32] = pat                        # plant an exact hit
        eng.corpus.set_rows(4, new_row)
        got = np.asarray(eng.scores(pat, backend="swar"))
        np.testing.assert_array_equal(
            got, sliding_scores(eng.corpus.fragments, pat))
        assert got[4, 20] == 12
        got_mxu = np.asarray(eng.scores(np.stack([pat]), backend="mxu"))
        np.testing.assert_array_equal(got_mxu[:, :, 0], got)
        assert eng.corpus.swar_pack_count == 1      # no repack on update
        assert eng.corpus.onehot_pack_count == 1


class TestStreamingReductions:
    def setup_method(self):
        rng = np.random.default_rng(11)
        self.frags = rng.integers(0, 4, (21, 120), np.uint8)
        self.pat = rng.integers(0, 4, 24, np.uint8)
        self.oracle = sliding_scores(self.frags, self.pat)

    def test_chunked_equals_unchunked(self):
        eng = MatchEngine(self.frags)
        whole = np.asarray(eng.scores(self.pat, backend="swar"))
        res = eng.match(self.pat, backend="swar", reduction="full",
                        chunk_rows=8)
        assert res.n_chunks == 3
        np.testing.assert_array_equal(res.scores, whole)

    def test_best_reduction(self):
        res = MatchEngine(self.frags).match(self.pat, backend="swar",
                                            reduction="best", chunk_rows=8)
        np.testing.assert_array_equal(res.best_scores, self.oracle.max(1))
        np.testing.assert_array_equal(res.best_locs, self.oracle.argmax(1))
        assert res.scores is None                  # never materialized

    def test_topk_reduction_across_chunks(self):
        res = MatchEngine(self.frags).match(self.pat, backend="swar",
                                            reduction="topk", k=5,
                                            chunk_rows=8)
        best = self.oracle.max(1)
        want_rows = np.argsort(-best, kind="stable")[:5]
        np.testing.assert_array_equal(np.sort(res.topk_scores)[::-1],
                                      res.topk_scores)
        np.testing.assert_array_equal(np.sort(best[want_rows]),
                                      np.sort(res.topk_scores))

    def test_threshold_reduction(self):
        thr = int(self.oracle.max()) - 1
        res = MatchEngine(self.frags).match(self.pat, backend="swar",
                                            reduction="threshold",
                                            threshold=thr, chunk_rows=8)
        want = np.argwhere(self.oracle >= thr)
        assert res.hits.shape == (want.shape[0], 3)
        np.testing.assert_array_equal(res.hits[:, :2], want)
        np.testing.assert_array_equal(
            res.hits[:, 2], self.oracle[tuple(want.T)])

    def test_batched_best_reduction(self):
        rng = np.random.default_rng(12)
        pats = rng.integers(0, 4, (3, 24), np.uint8)
        res = MatchEngine(self.frags).match(pats, backend="mxu",
                                            reduction="best", chunk_rows=8)
        want = np.stack([sliding_scores(self.frags, pats[i]).max(1)
                         for i in range(3)], -1)
        np.testing.assert_array_equal(res.best_scores, want)


class TestSharded:
    def test_one_device_mesh(self):
        """A 1-device mesh runs the full engine path end to end."""
        rng = np.random.default_rng(20)
        frags = rng.integers(0, 4, (10, 64), np.uint8)
        pat = rng.integers(0, 4, 16, np.uint8)
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        eng = MatchEngine(frags, mesh=mesh)
        np.testing.assert_array_equal(
            np.asarray(eng.scores(pat, backend="swar")),
            sliding_scores(frags, pat))

    def test_multi_device_shard_map(self):
        """Rows shard over the data axis (shard_map); needs >= 2 devices
        (run under forced host device count to exercise)."""
        if len(jax.devices()) < 2:
            pytest.skip("needs >= 2 devices")
        rng = np.random.default_rng(21)
        frags = rng.integers(0, 4, (10, 64), np.uint8)
        pat = rng.integers(0, 4, 16, np.uint8)
        mesh = jax.make_mesh((2,), ("data",))
        eng = MatchEngine(frags, mesh=mesh)
        assert eng._row_shards == 2
        np.testing.assert_array_equal(
            np.asarray(eng.scores(pat, backend="swar")),
            sliding_scores(frags, pat))
        pats = rng.integers(0, 4, (3, 16), np.uint8)
        got = np.asarray(eng.scores(pats, backend="mxu"))
        want = np.stack([sliding_scores(frags, pats[i]) for i in range(3)],
                        -1)
        np.testing.assert_array_equal(got, want)


class TestModeAndSubsets:
    def test_batched_q_equals_r_via_mxu(self):
        """Historical ops semantics: 2-D patterns on mxu are batched even
        when Q happens to equal the corpus row count."""
        frags, pats = case(3, 60, 12, q=3, seed=40)
        got = np.asarray(MatchEngine(frags).scores(pats, backend="mxu"))
        want = np.stack([sliding_scores(frags, pats[i]) for i in range(3)],
                        -1)
        assert got.shape == (3, 49, 3)
        np.testing.assert_array_equal(got, want)

    def test_explicit_mode_batched_on_swar(self):
        frags, pats = case(4, 50, 10, q=4, seed=41)
        got = np.asarray(MatchEngine(frags).scores(pats, backend="swar",
                                                   mode="batched"))
        want = np.stack([sliding_scores(frags, pats[i]) for i in range(4)],
                        -1)
        np.testing.assert_array_equal(got, want)

    def test_explicit_mode_per_row_wrong_rows_rejected(self):
        frags, _ = case(6, 50, 10, seed=42)
        pats = np.zeros((4, 10), np.uint8)
        with pytest.raises(ValueError, match="one row per"):
            MatchEngine(frags).scores(pats, mode="per_row")

    def test_row_subset_query(self):
        """rows= gathers from the resident forms -- results in subset
        order, no repacking."""
        rng = np.random.default_rng(43)
        frags = rng.integers(0, 4, (20, 80), np.uint8)
        pat = rng.integers(0, 4, 16, np.uint8)
        eng = MatchEngine(frags)
        eng.scores(pat, backend="swar")              # pack once
        sub = [17, 3, 11, 5, 2]
        got = np.asarray(eng.scores(pat, backend="swar", rows=sub))
        np.testing.assert_array_equal(got, sliding_scores(frags[sub], pat))
        assert eng.corpus.host_pack_count == 1       # gather, not repack

    def test_row_subset_per_row(self):
        rng = np.random.default_rng(44)
        frags = rng.integers(0, 4, (15, 60), np.uint8)
        sub = [1, 8, 14]
        pats = rng.integers(0, 4, (3, 12), np.uint8)
        res = MatchEngine(frags).match(pats, backend="swar", mode="per_row",
                                       rows=sub, reduction="best")
        want = sliding_scores(frags[sub], pats)
        np.testing.assert_array_equal(res.best_scores, want.max(1))

    def test_row_subset_topk_reports_corpus_row_ids(self):
        rng = np.random.default_rng(47)
        frags = rng.integers(0, 4, (12, 60), np.uint8)
        pat = rng.integers(0, 4, 12, np.uint8)
        sub = [7, 2, 5, 9]
        res = MatchEngine(frags).match(pat, backend="swar", rows=sub,
                                       reduction="topk", k=2, chunk_rows=8)
        best = sliding_scores(frags[sub], pat).max(1)
        order = np.argsort(-best, kind="stable")[:2]
        assert set(res.topk_rows.tolist()) <= set(sub)
        np.testing.assert_array_equal(np.sort(res.topk_scores),
                                      np.sort(best[order]))

    def test_row_subset_threshold_reports_corpus_row_ids(self):
        rng = np.random.default_rng(48)
        frags = rng.integers(0, 4, (12, 60), np.uint8)
        pat = rng.integers(0, 4, 12, np.uint8)
        sub = [7, 2, 5]
        oracle = sliding_scores(frags[sub], pat)
        thr = int(oracle.max())
        res = MatchEngine(frags).match(pat, backend="swar", rows=sub,
                                       reduction="threshold", threshold=thr)
        want = np.argwhere(oracle >= thr)
        assert res.hits.shape[0] == want.shape[0] > 0
        np.testing.assert_array_equal(
            res.hits[:, 0], np.asarray(sub)[want[:, 0]])

    def test_out_of_range_rows_rejected(self):
        frags, pat = case(8, 40, 10, seed=49)
        eng = MatchEngine(frags)
        with pytest.raises(IndexError, match="rows must be in"):
            eng.scores(pat, rows=[99], backend="swar")
        with pytest.raises(IndexError, match="rows must be in"):
            eng.scores(pat, rows=[-1])

    def test_corpus_does_not_alias_caller_array(self):
        rng = np.random.default_rng(45)
        frags = rng.integers(0, 4, (8, 40), np.uint8)
        keep = frags.copy()
        eng = MatchEngine(frags)
        eng.corpus.set_rows(0, np.ones(40, np.uint8))
        np.testing.assert_array_equal(frags, keep)   # caller untouched


class TestPlannerBatchPricing:
    def setup_method(self):
        self.planner = Planner()

    def test_tiny_threshold_includes_batch_size(self):
        """R*L*P alone calls a large batched query on a small corpus
        'tiny' and routes it to the Python-loop ref backend (Q sequential
        passes); the ops estimate must include Q."""
        kw = dict(n_rows=2, fragment_chars=20, pattern_chars=8)
        assert self.planner.plan(**kw).backend == "ref"
        assert self.planner.plan(**kw, n_patterns=64).backend != "ref"

    def test_tiny_q_boundary(self):
        # R*L*P = 2*13*8 = 208; Q=19 -> 3952 <= 4096 stays ref, Q=20 spills.
        kw = dict(n_rows=2, fragment_chars=20, pattern_chars=8)
        assert self.planner.plan(**kw, n_patterns=19).backend == "ref"
        assert self.planner.plan(**kw, n_patterns=20).backend != "ref"

    def test_plan_batch_coalesces_large_q(self):
        bp = self.planner.plan_batch(n_rows=512, fragment_chars=1024,
                                     pattern_chars=100, n_queries=64)
        assert bp.coalesced and bp.plan.mode == "batched"
        assert bp.plan.n_patterns == 64
        assert bp.est_coalesced_s <= bp.est_sequential_s

    def test_plan_batch_single_query_is_sequential(self):
        bp = self.planner.plan_batch(n_rows=512, fragment_chars=1024,
                                     pattern_chars=100, n_queries=1)
        assert not bp.coalesced and bp.plan.mode == "shared"

    def test_plan_batch_respects_backend_override(self):
        bp = self.planner.plan_batch(n_rows=64, fragment_chars=256,
                                     pattern_chars=32, n_queries=8,
                                     backend="swar")
        assert bp.plan.backend == "swar"

    def test_ref_estimate_nonzero(self):
        p = self.planner.plan(n_rows=2, fragment_chars=20, pattern_chars=8)
        assert p.backend == "ref" and p.est_seconds > 0


class TestEmptySubsetsAndEmptyCorpus:
    def setup_method(self):
        rng = np.random.default_rng(50)
        self.frags = rng.integers(0, 4, (10, 64), np.uint8)
        self.pat = rng.integers(0, 4, 16, np.uint8)
        self.empty = np.array([], dtype=int)

    def test_empty_corpus_rejected_at_construction(self):
        with pytest.raises(ValueError, match="non-empty corpus"):
            MatchEngine(np.zeros((0, 16), np.uint8))
        with pytest.raises(ValueError, match="non-empty corpus"):
            MatchEngine(PackedCorpus(np.zeros((0, 16), np.uint8)))

    @pytest.mark.parametrize("reduction", ["best", "topk", "full"])
    def test_empty_subset_shared(self, reduction):
        res = MatchEngine(self.frags).match(self.pat, rows=self.empty,
                                            reduction=reduction)
        assert res.best_locs.shape == (0,)
        assert res.best_scores.shape == (0,)
        assert res.n_chunks == 0 and res.plan.n_rows == 0
        if reduction == "topk":
            assert res.topk_rows.shape == (0,)
            assert res.topk_scores.shape == (0,)
        if reduction == "full":
            assert res.scores.shape == (0, 64 - 16 + 1)

    def test_empty_subset_threshold(self):
        res = MatchEngine(self.frags).match(self.pat, rows=self.empty,
                                            reduction="threshold",
                                            threshold=1)
        assert res.hits.shape == (0, 3)

    def test_empty_subset_batched(self):
        pats = np.zeros((3, 16), np.uint8)
        res = MatchEngine(self.frags).match(pats, rows=self.empty,
                                            reduction="best")
        assert res.best_scores.shape == (0, 3)
        res = MatchEngine(self.frags).match(pats, rows=self.empty,
                                            reduction="threshold",
                                            threshold=1)
        assert res.hits.shape == (0, 4)

    def test_empty_subset_still_validates_pattern(self):
        with pytest.raises(ValueError, match="longer"):
            MatchEngine(self.frags).match(np.zeros(65, np.uint8),
                                          rows=self.empty)


class TestSubsetReductionsAllBackends:
    """topk / threshold under rows= subsets and k > R, on every backend."""

    def setup_method(self):
        rng = np.random.default_rng(51)
        self.frags = rng.integers(0, 4, (14, 72), np.uint8)
        self.pat = rng.integers(0, 4, 18, np.uint8)
        self.sub = [11, 3, 7, 0, 9]
        self.oracle = sliding_scores(self.frags[self.sub], self.pat)

    @pytest.mark.parametrize("backend", ["swar", "mxu", "ref"])
    def test_topk_rows_subset(self, backend):
        res = MatchEngine(self.frags).match(
            self.pat, backend=backend, rows=self.sub, reduction="topk", k=3)
        best = self.oracle.max(1)
        assert res.topk_rows.shape == (3,)
        assert set(res.topk_rows.tolist()) <= set(self.sub)
        np.testing.assert_array_equal(np.sort(res.topk_scores),
                                      np.sort(np.sort(best)[-3:]))

    @pytest.mark.parametrize("backend", ["swar", "mxu", "ref"])
    def test_topk_k_exceeds_rows(self, backend):
        """k > R clamps to the row count instead of padding or crashing."""
        res = MatchEngine(self.frags).match(
            self.pat, backend=backend, rows=self.sub, reduction="topk",
            k=50)
        assert res.topk_rows.shape == (len(self.sub),)
        assert sorted(res.topk_rows.tolist()) == sorted(self.sub)
        np.testing.assert_array_equal(np.sort(res.topk_scores),
                                      np.sort(self.oracle.max(1)))

    @pytest.mark.parametrize("backend", ["swar", "mxu", "ref"])
    def test_topk_k_exceeds_full_corpus(self, backend):
        res = MatchEngine(self.frags).match(self.pat, backend=backend,
                                            reduction="topk", k=99)
        assert res.topk_rows.shape == (self.frags.shape[0],)

    @pytest.mark.parametrize("backend", ["swar", "mxu", "ref"])
    def test_threshold_rows_subset(self, backend):
        thr = int(self.oracle.max()) - 1
        res = MatchEngine(self.frags).match(
            self.pat, backend=backend, rows=self.sub,
            reduction="threshold", threshold=thr)
        want = np.argwhere(self.oracle >= thr)
        assert res.hits.shape == (want.shape[0], 3)
        np.testing.assert_array_equal(
            res.hits[:, 0], np.asarray(self.sub)[want[:, 0]])
        np.testing.assert_array_equal(res.hits[:, 1], want[:, 1])
        np.testing.assert_array_equal(res.hits[:, 2],
                                      self.oracle[tuple(want.T)])

    def test_batched_per_query_thresholds(self):
        rng = np.random.default_rng(52)
        pats = rng.integers(0, 4, (3, 18), np.uint8)
        oracles = [sliding_scores(self.frags, pats[i]) for i in range(3)]
        thrs = [int(o.max()) for o in oracles]
        res = MatchEngine(self.frags).match(pats, mode="batched",
                                            reduction="threshold",
                                            threshold=thrs)
        for q in range(3):
            mine = res.hits[res.hits[:, 2] == q]
            want = np.argwhere(oracles[q] >= thrs[q])
            np.testing.assert_array_equal(mine[:, :2], want)

    def test_batched_per_query_k(self):
        rng = np.random.default_rng(53)
        pats = rng.integers(0, 4, (3, 18), np.uint8)
        ks = [2, 5, 9]
        res = MatchEngine(self.frags).match(pats, mode="batched",
                                            reduction="topk", k=ks)
        # Merge runs at max(k); per-query slices reproduce the solo runs.
        assert res.topk_rows.shape == (9, 3)
        for q, kq in enumerate(ks):
            solo = MatchEngine(self.frags).match(pats[q], reduction="topk",
                                                 k=kq)
            np.testing.assert_array_equal(res.topk_scores[:kq, q],
                                          solo.topk_scores)

    def test_per_query_k_rejected_outside_batched(self):
        with pytest.raises(ValueError, match="per-query k"):
            MatchEngine(self.frags).match(self.pat, reduction="topk",
                                          k=[1, 2])


class TestDedupLifetimeCounters:
    def test_counters_survive_capacity_growth(self):
        from repro.data.dedup import CRAMDedup
        rng = np.random.default_rng(46)
        d = CRAMDedup(threshold=1.01)                # never a duplicate
        n = 70                                       # forces one doubling
        kept = d.filter([rng.bytes(64) for _ in range(n)])
        assert len(kept) == n and len(d) == n and d.capacity == 128
        assert d.total_row_writes == n
        # One pack per capacity generation that served queries; never per add.
        assert 1 <= d.total_host_packs <= 2


class TestCompatShim:
    def test_ops_match_scores_auto(self):
        from repro.kernels import ops
        frags, pat = case(6, 80, 20, seed=30)
        got = np.asarray(ops.match_scores(frags, pat))
        np.testing.assert_array_equal(got, sliding_scores(frags, pat))

    def test_corpus_from_reference_roundtrip(self):
        from repro.core import encoding
        rng = np.random.default_rng(31)
        genome = encoding.random_dna(rng, 5000)
        corpus = PackedCorpus.from_reference(genome, 500, 100)
        pat = genome[1234:1334]
        res = MatchEngine(corpus).match(pat, reduction="best")
        step = 500 - 99
        row = int(np.argmax(res.best_scores))
        assert res.best_scores[row] == 100
        assert row * step + res.best_locs[row] == 1234
