"""pixtral-12b [vlm]: Pixtral-ViT frontend (STUB) + Mistral-Nemo backbone.

[hf:mistralai/Pixtral-12B-2409; unverified]  40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072, head_dim=128.  The vision frontend is a stub:
``input_specs`` supplies precomputed patch/text embeddings (DESIGN.md
Arch-applicability).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072,
    rope_theta=1e6, act="silu", norm="rms",
    input_mode="embeddings",
    microbatch=4,
)

SMOKE = ModelConfig(
    name="pixtral-12b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, rope_theta=1e4, input_mode="embeddings",
    tp_pad=1, vocab_pad=1, remat=False, attn_block_q=32, attn_block_kv=32,
)
