"""Paper Fig. 6: energy/latency breakdown by stage (unoptimized design).
Paper anchors: preset 43.86% energy / 97.25% latency; BL <1% / 2.7%;
writes <1%/<1%."""

import time

from repro.core import costmodel as cm
from repro.core.tech import NEAR_TERM


def run():
    t0 = time.perf_counter()
    pc = cm.pass_cost(cm.Design(tech=NEAR_TERM, opt=False))
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    for stage in sorted(pc.stages):
        rows.append((
            f"fig6/{stage}", round(us, 1),
            f"lat_share={pc.share(stage, 'latency'):.4f}"
            f" energy_share={pc.share(stage, 'energy'):.4f}"))
    rows.append(("fig6/paper_anchor", 0.0,
                 "preset paper=0.4386 energy / 0.9725 latency"))
    return rows
